//! Length-prefixed frame protocol for the cluster plane.
//!
//! Every message between cluster processes travels as one frame:
//!
//! ```text
//! +----------------+-----+------------------+----------------------+
//! | body_len (u32) | tag | body (body_len)  | crc32(tag ‖ body)    |
//! | little-endian  | u8  | message payload  | u32 little-endian    |
//! +----------------+-----+------------------+----------------------+
//! ```
//!
//! The CRC trailer reuses the tree's one [`Crc32`] implementation
//! ([`crate::util::crc32`] — the same table the PFS block path verifies
//! with, cross-checked there against pinned vectors) and
//! covers the tag byte *and* the body, so a bit-flip anywhere past the
//! length prefix surfaces as [`WireKind::Crc`]. Corruption of the length
//! prefix itself surfaces as [`WireKind::Oversized`] (length beyond
//! [`MAX_FRAME`]), [`WireKind::Truncated`] (stream ends early), or —
//! if the mangled length still lands on readable bytes — a CRC failure.
//! A clean EOF *between* frames is not an error: [`read_frame`] returns
//! `Ok(None)` so callers can distinguish an orderly close from a cut.
//!
//! Connections open with a versioned [`Message::Hello`]; a peer speaking
//! a different [`WIRE_VERSION`] is rejected with [`WireKind::Version`]
//! before any other traffic.

use std::io::{Read, Write};

use crate::error::{Error, Result, WireKind};
use crate::util::crc32::Crc32;

/// Protocol version carried in every [`Message::Hello`]. Bump on any
/// incompatible frame- or message-layout change.
pub const WIRE_VERSION: u32 = 1;

/// Maximum frame body size (32 MiB). A length prefix beyond this is
/// rejected as [`WireKind::Oversized`] *before* allocating, so a
/// corrupt or hostile length field cannot balloon memory.
pub const MAX_FRAME: u32 = 32 << 20;

/// Which side of the protocol a connecting peer is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// A task-executing worker connecting to the coordinator.
    Worker,
    /// An [`ObjectStore`](crate::storage::ObjectStore) client connecting
    /// to a PFS stripe server.
    PfsClient,
}

impl Role {
    fn to_u8(self) -> u8 {
        match self {
            Role::Worker => 1,
            Role::PfsClient => 2,
        }
    }

    fn from_u8(v: u8) -> Result<Self> {
        match v {
            1 => Ok(Role::Worker),
            2 => Ok(Role::PfsClient),
            _ => Err(Error::wire(
                WireKind::Malformed,
                format!("unknown role byte {v:#04x}"),
            )),
        }
    }
}

/// What a dispatched task does. Travels inside [`TaskSpec`] over the
/// wire; workers execute it against the shared store.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TaskKind {
    /// Read one input split, sort it, partition it, and write one sorted
    /// spill object per non-empty partition.
    Map {
        /// Input object holding the split.
        object: String,
        /// Byte offset of the split within the object.
        offset: u64,
        /// Split length in bytes.
        len: u64,
        /// Map-task index (names the spill objects).
        task_index: u32,
        /// Number of reduce partitions.
        partitions: u32,
        /// 256-entry first-key-byte → partition table (the sampled
        /// [`Partitioner`](crate::terasort::Partitioner) serialized).
        bucket_map: Vec<u32>,
        /// Key prefix the task writes spills under
        /// (`.shuffle/<job>/`).
        shuffle_prefix: String,
    },
    /// Merge the sorted spills of one partition into one output object.
    Reduce {
        /// Partition index this reducer owns.
        partition: u32,
        /// Sorted spill objects to k-way merge.
        spill_keys: Vec<String>,
        /// Output object key (`part-r-NNNNN`).
        out_key: String,
    },
}

/// One unit of dispatched work: identity, attempt counter, placement
/// hint, and the [`TaskKind`] payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskSpec {
    /// Coordinator-assigned id, unique within the job.
    pub task_id: u64,
    /// Epoch-namespaced job id the task belongs to.
    pub job_id: String,
    /// 0-based execution attempt (bumped on re-dispatch after worker
    /// loss, so retried spill keys never collide with a dead attempt's).
    pub attempt: u32,
    /// Scheduler placement hint: the node index whose worker should run
    /// this task for a locality hit, if any.
    pub preferred_node: Option<u32>,
    /// The work itself.
    pub kind: TaskKind,
}

/// Per-task I/O accounting split by storage tier, carried inside
/// [`Message::TaskDone`]. A worker running a two-level store reports
/// how many bytes (and how much storage-call busy time) each direction
/// served from its local memory tier versus the remote PFS tier — the
/// observable `f` of the paper's eq. (7). Plain (untiered) workers
/// send an empty (all-zero) accounting, which the coordinator leaves
/// out of the per-tier timelines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TierIo {
    /// Read bytes served by the worker-local memory tier.
    pub mem_read_bytes: u64,
    /// Busy-microseconds of memory-tier reads.
    pub mem_read_micros: u64,
    /// Read bytes served by the remote PFS tier.
    pub remote_read_bytes: u64,
    /// Busy-microseconds of remote-tier reads.
    pub remote_read_micros: u64,
    /// Write bytes that landed only in the memory tier (spills).
    pub mem_write_bytes: u64,
    /// Busy-microseconds of memory-tier writes.
    pub mem_write_micros: u64,
    /// Write bytes that landed on the remote PFS tier.
    pub remote_write_bytes: u64,
    /// Busy-microseconds of remote-tier writes.
    pub remote_write_micros: u64,
    /// Wall microseconds of the task that produced this accounting —
    /// `busy_micros() / wall_micros` is the task's overlap efficiency
    /// (how much of its lifetime the storage planes were kept busy).
    /// Zero from untiered workers, whose accounting is all-zero.
    pub wall_micros: u64,
}

impl TierIo {
    /// True when no tiered traffic was recorded.
    pub fn is_empty(&self) -> bool {
        *self == TierIo::default()
    }

    /// Storage busy-microseconds summed over both tiers and directions.
    pub fn busy_micros(&self) -> u64 {
        self.mem_read_micros
            + self.remote_read_micros
            + self.mem_write_micros
            + self.remote_write_micros
    }

    /// Storage busy-time per wall-second of the reporting task; `0.0`
    /// until a wall time was recorded.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.wall_micros == 0 {
            return 0.0;
        }
        self.busy_micros() as f64 / self.wall_micros as f64
    }
}

fn enc_tier_io(e: &mut Enc, t: &TierIo) {
    e.u64(t.mem_read_bytes);
    e.u64(t.mem_read_micros);
    e.u64(t.remote_read_bytes);
    e.u64(t.remote_read_micros);
    e.u64(t.mem_write_bytes);
    e.u64(t.mem_write_micros);
    e.u64(t.remote_write_bytes);
    e.u64(t.remote_write_micros);
    e.u64(t.wall_micros);
}

fn dec_tier_io(d: &mut Dec<'_>) -> Result<TierIo> {
    Ok(TierIo {
        mem_read_bytes: d.u64("tier.mem_read_bytes")?,
        mem_read_micros: d.u64("tier.mem_read_micros")?,
        remote_read_bytes: d.u64("tier.remote_read_bytes")?,
        remote_read_micros: d.u64("tier.remote_read_micros")?,
        mem_write_bytes: d.u64("tier.mem_write_bytes")?,
        mem_write_micros: d.u64("tier.mem_write_micros")?,
        remote_write_bytes: d.u64("tier.remote_write_bytes")?,
        remote_write_micros: d.u64("tier.remote_write_micros")?,
        wall_micros: d.u64("tier.wall_micros")?,
    })
}

/// Every message the cluster protocol defines. Tag bytes are grouped:
/// `0x0x` handshake, `0x1x` PFS requests, `0x2x` PFS replies, `0x3x`
/// coordinator/worker control.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Message {
    /// First frame on every connection: protocol version, peer role,
    /// and the cluster epoch the peer believes it is joining (0 from
    /// peers that take the epoch from the coordinator's ack).
    Hello {
        version: u32,
        role: Role,
        epoch: u64,
    },
    /// Handshake reply: server's version, the authoritative cluster
    /// epoch, and (for workers) the assigned worker id.
    HelloAck {
        version: u32,
        epoch: u64,
        worker_id: u64,
    },

    /// Store a whole object under `key` (PFS request).
    Put { key: String, data: Vec<u8> },
    /// Read `len` bytes of `key` starting at `offset`, clamped at EOF.
    GetRange { key: String, offset: u64, len: u32 },
    /// Object metadata for `key`.
    Stat { key: String },
    /// Delete `key` (idempotent).
    Delete { key: String },
    /// Sorted keys under `prefix`.
    List { prefix: String },
    /// Read the whole object under `key`.
    Get { key: String },
    /// Atomically re-key `from` to `to` on one server (the wire mirror
    /// of [`Pfs`](crate::storage::pfs::Pfs)'s temp-file rename
    /// discipline: stripe writers stage under token-suffixed keys and
    /// rename at commit).
    Rename { from: String, to: String },

    /// PFS reply: success, no payload.
    OkUnit,
    /// PFS reply: byte payload (Get / GetRange).
    OkBytes { data: Vec<u8> },
    /// PFS reply: object size (Stat).
    OkMeta { size: u64 },
    /// PFS reply: key list (List).
    OkKeys { keys: Vec<String> },
    /// PFS reply: the remote operation failed. `code` 1 means
    /// not-found (mapped back to [`Error::NotFound`] client-side);
    /// anything else becomes [`WireKind::Remote`].
    ErrReply { code: u8, msg: String },

    /// Worker liveness beat.
    Heartbeat { worker_id: u64 },
    /// Coordinator's beat acknowledgement.
    HeartbeatAck,
    /// Worker asks for its next task (blocks until the coordinator has
    /// one, the job finishes, or the job fails).
    ReqTask { worker_id: u64 },
    /// Coordinator dispatches a task.
    TaskAssign(TaskSpec),
    /// Coordinator has no more work: the job finished (`failed=false`)
    /// or failed (`failed=true`, with the diagnosis in `msg`).
    NoTask { failed: bool, msg: String },
    /// Worker finished a task; carries the spill objects it produced
    /// (partition → key) and its I/O accounting for the per-worker
    /// timelines.
    TaskDone {
        worker_id: u64,
        task_id: u64,
        spills: Vec<(u32, String)>,
        bytes_read: u64,
        bytes_written: u64,
        micros: u64,
        tier_io: TierIo,
    },
    /// Worker failed a task but is still alive.
    TaskFail {
        worker_id: u64,
        task_id: u64,
        error: String,
    },
}

// Tag bytes (must stay stable across releases of the same WIRE_VERSION).
const TAG_HELLO: u8 = 0x01;
const TAG_HELLO_ACK: u8 = 0x02;
const TAG_PUT: u8 = 0x10;
const TAG_GET_RANGE: u8 = 0x11;
const TAG_STAT: u8 = 0x12;
const TAG_DELETE: u8 = 0x13;
const TAG_LIST: u8 = 0x14;
const TAG_GET: u8 = 0x15;
const TAG_RENAME: u8 = 0x16;
const TAG_OK_UNIT: u8 = 0x20;
const TAG_OK_BYTES: u8 = 0x21;
const TAG_OK_META: u8 = 0x22;
const TAG_OK_KEYS: u8 = 0x23;
const TAG_ERR_REPLY: u8 = 0x2F;
const TAG_HEARTBEAT: u8 = 0x30;
const TAG_HEARTBEAT_ACK: u8 = 0x31;
const TAG_REQ_TASK: u8 = 0x32;
const TAG_TASK_ASSIGN: u8 = 0x33;
const TAG_NO_TASK: u8 = 0x34;
const TAG_TASK_DONE: u8 = 0x35;
const TAG_TASK_FAIL: u8 = 0x36;

const KIND_MAP: u8 = 1;
const KIND_REDUCE: u8 = 2;

/// Message-body encoder: little-endian scalars, length-prefixed strings
/// and lists.
struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Self {
        Self { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn boolean(&mut self, v: bool) {
        self.u8(v as u8);
    }

    fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    fn str_list(&mut self, v: &[String]) {
        self.u32(v.len() as u32);
        for s in v {
            self.str(s);
        }
    }

    fn u32_list(&mut self, v: &[u32]) {
        self.u32(v.len() as u32);
        for &x in v {
            self.u32(x);
        }
    }

    fn opt_u32(&mut self, v: Option<u32>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u32(x);
            }
            None => self.u8(0),
        }
    }
}

/// Message-body decoder; every short read or ill-formed field is a
/// typed [`WireKind::Malformed`], never a panic.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn malformed(what: &str) -> Error {
        Error::wire(WireKind::Malformed, format!("short read decoding {what}"))
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(Self::malformed(what));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn boolean(&mut self, what: &str) -> Result<bool> {
        match self.u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(Error::wire(
                WireKind::Malformed,
                format!("bad bool byte {v:#04x} decoding {what}"),
            )),
        }
    }

    fn bytes(&mut self, what: &str) -> Result<Vec<u8>> {
        let n = self.u32(what)? as usize;
        Ok(self.take(n, what)?.to_vec())
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let raw = self.bytes(what)?;
        String::from_utf8(raw)
            .map_err(|_| Error::wire(WireKind::Malformed, format!("bad utf-8 decoding {what}")))
    }

    fn str_list(&mut self, what: &str) -> Result<Vec<String>> {
        let n = self.u32(what)? as usize;
        // Each entry costs ≥4 bytes; reject absurd counts before
        // reserving.
        if n > self.buf.len() - self.pos {
            return Err(Self::malformed(what));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.str(what)?);
        }
        Ok(out)
    }

    fn u32_list(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.u32(what)? as usize;
        if n > self.buf.len() - self.pos {
            return Err(Self::malformed(what));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32(what)?);
        }
        Ok(out)
    }

    fn opt_u32(&mut self, what: &str) -> Result<Option<u32>> {
        if self.boolean(what)? {
            Ok(Some(self.u32(what)?))
        } else {
            Ok(None)
        }
    }

    fn finish(self, what: &str) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::wire(
                WireKind::Malformed,
                format!(
                    "{} trailing bytes after {what}",
                    self.buf.len() - self.pos
                ),
            ));
        }
        Ok(())
    }
}

fn enc_spec(e: &mut Enc, spec: &TaskSpec) {
    e.u64(spec.task_id);
    e.str(&spec.job_id);
    e.u32(spec.attempt);
    e.opt_u32(spec.preferred_node);
    match &spec.kind {
        TaskKind::Map {
            object,
            offset,
            len,
            task_index,
            partitions,
            bucket_map,
            shuffle_prefix,
        } => {
            e.u8(KIND_MAP);
            e.str(object);
            e.u64(*offset);
            e.u64(*len);
            e.u32(*task_index);
            e.u32(*partitions);
            e.u32_list(bucket_map);
            e.str(shuffle_prefix);
        }
        TaskKind::Reduce {
            partition,
            spill_keys,
            out_key,
        } => {
            e.u8(KIND_REDUCE);
            e.u32(*partition);
            e.str_list(spill_keys);
            e.str(out_key);
        }
    }
}

fn dec_spec(d: &mut Dec<'_>) -> Result<TaskSpec> {
    let task_id = d.u64("task.id")?;
    let job_id = d.str("task.job_id")?;
    let attempt = d.u32("task.attempt")?;
    let preferred_node = d.opt_u32("task.preferred_node")?;
    let kind = match d.u8("task.kind")? {
        KIND_MAP => TaskKind::Map {
            object: d.str("map.object")?,
            offset: d.u64("map.offset")?,
            len: d.u64("map.len")?,
            task_index: d.u32("map.task_index")?,
            partitions: d.u32("map.partitions")?,
            bucket_map: d.u32_list("map.bucket_map")?,
            shuffle_prefix: d.str("map.shuffle_prefix")?,
        },
        KIND_REDUCE => TaskKind::Reduce {
            partition: d.u32("reduce.partition")?,
            spill_keys: d.str_list("reduce.spill_keys")?,
            out_key: d.str("reduce.out_key")?,
        },
        v => {
            return Err(Error::wire(
                WireKind::Malformed,
                format!("unknown task kind byte {v:#04x}"),
            ))
        }
    };
    Ok(TaskSpec {
        task_id,
        job_id,
        attempt,
        preferred_node,
        kind,
    })
}

impl Message {
    /// Serialize to `(tag, body)` — the two CRC-covered frame fields.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        let mut e = Enc::new();
        let tag = match self {
            Message::Hello {
                version,
                role,
                epoch,
            } => {
                e.u32(*version);
                e.u8(role.to_u8());
                e.u64(*epoch);
                TAG_HELLO
            }
            Message::HelloAck {
                version,
                epoch,
                worker_id,
            } => {
                e.u32(*version);
                e.u64(*epoch);
                e.u64(*worker_id);
                TAG_HELLO_ACK
            }
            Message::Put { key, data } => {
                e.str(key);
                e.bytes(data);
                TAG_PUT
            }
            Message::GetRange { key, offset, len } => {
                e.str(key);
                e.u64(*offset);
                e.u32(*len);
                TAG_GET_RANGE
            }
            Message::Stat { key } => {
                e.str(key);
                TAG_STAT
            }
            Message::Delete { key } => {
                e.str(key);
                TAG_DELETE
            }
            Message::List { prefix } => {
                e.str(prefix);
                TAG_LIST
            }
            Message::Get { key } => {
                e.str(key);
                TAG_GET
            }
            Message::Rename { from, to } => {
                e.str(from);
                e.str(to);
                TAG_RENAME
            }
            Message::OkUnit => TAG_OK_UNIT,
            Message::OkBytes { data } => {
                e.bytes(data);
                TAG_OK_BYTES
            }
            Message::OkMeta { size } => {
                e.u64(*size);
                TAG_OK_META
            }
            Message::OkKeys { keys } => {
                e.str_list(keys);
                TAG_OK_KEYS
            }
            Message::ErrReply { code, msg } => {
                e.u8(*code);
                e.str(msg);
                TAG_ERR_REPLY
            }
            Message::Heartbeat { worker_id } => {
                e.u64(*worker_id);
                TAG_HEARTBEAT
            }
            Message::HeartbeatAck => TAG_HEARTBEAT_ACK,
            Message::ReqTask { worker_id } => {
                e.u64(*worker_id);
                TAG_REQ_TASK
            }
            Message::TaskAssign(spec) => {
                enc_spec(&mut e, spec);
                TAG_TASK_ASSIGN
            }
            Message::NoTask { failed, msg } => {
                e.boolean(*failed);
                e.str(msg);
                TAG_NO_TASK
            }
            Message::TaskDone {
                worker_id,
                task_id,
                spills,
                bytes_read,
                bytes_written,
                micros,
                tier_io,
            } => {
                e.u64(*worker_id);
                e.u64(*task_id);
                e.u32(spills.len() as u32);
                for (p, key) in spills {
                    e.u32(*p);
                    e.str(key);
                }
                e.u64(*bytes_read);
                e.u64(*bytes_written);
                e.u64(*micros);
                enc_tier_io(&mut e, tier_io);
                TAG_TASK_DONE
            }
            Message::TaskFail {
                worker_id,
                task_id,
                error,
            } => {
                e.u64(*worker_id);
                e.u64(*task_id);
                e.str(error);
                TAG_TASK_FAIL
            }
        };
        (tag, e.buf)
    }

    /// Parse a CRC-verified `(tag, body)` pair back into a message.
    /// Unknown tags are [`WireKind::UnknownTag`]; any structural flaw in
    /// the body is [`WireKind::Malformed`].
    pub fn decode(tag: u8, body: &[u8]) -> Result<Message> {
        let mut d = Dec::new(body);
        let msg = match tag {
            TAG_HELLO => Message::Hello {
                version: d.u32("hello.version")?,
                role: Role::from_u8(d.u8("hello.role")?)?,
                epoch: d.u64("hello.epoch")?,
            },
            TAG_HELLO_ACK => Message::HelloAck {
                version: d.u32("ack.version")?,
                epoch: d.u64("ack.epoch")?,
                worker_id: d.u64("ack.worker_id")?,
            },
            TAG_PUT => Message::Put {
                key: d.str("put.key")?,
                data: d.bytes("put.data")?,
            },
            TAG_GET_RANGE => Message::GetRange {
                key: d.str("get_range.key")?,
                offset: d.u64("get_range.offset")?,
                len: d.u32("get_range.len")?,
            },
            TAG_STAT => Message::Stat {
                key: d.str("stat.key")?,
            },
            TAG_DELETE => Message::Delete {
                key: d.str("delete.key")?,
            },
            TAG_LIST => Message::List {
                prefix: d.str("list.prefix")?,
            },
            TAG_GET => Message::Get {
                key: d.str("get.key")?,
            },
            TAG_RENAME => Message::Rename {
                from: d.str("rename.from")?,
                to: d.str("rename.to")?,
            },
            TAG_OK_UNIT => Message::OkUnit,
            TAG_OK_BYTES => Message::OkBytes {
                data: d.bytes("ok.data")?,
            },
            TAG_OK_META => Message::OkMeta {
                size: d.u64("ok.size")?,
            },
            TAG_OK_KEYS => Message::OkKeys {
                keys: d.str_list("ok.keys")?,
            },
            TAG_ERR_REPLY => Message::ErrReply {
                code: d.u8("err.code")?,
                msg: d.str("err.msg")?,
            },
            TAG_HEARTBEAT => Message::Heartbeat {
                worker_id: d.u64("beat.worker_id")?,
            },
            TAG_HEARTBEAT_ACK => Message::HeartbeatAck,
            TAG_REQ_TASK => Message::ReqTask {
                worker_id: d.u64("req.worker_id")?,
            },
            TAG_TASK_ASSIGN => Message::TaskAssign(dec_spec(&mut d)?),
            TAG_NO_TASK => Message::NoTask {
                failed: d.boolean("no_task.failed")?,
                msg: d.str("no_task.msg")?,
            },
            TAG_TASK_DONE => {
                let worker_id = d.u64("done.worker_id")?;
                let task_id = d.u64("done.task_id")?;
                let n = d.u32("done.spills")? as usize;
                if n > body.len() {
                    return Err(Dec::malformed("done.spills"));
                }
                let mut spills = Vec::with_capacity(n);
                for _ in 0..n {
                    let p = d.u32("done.spill.partition")?;
                    let key = d.str("done.spill.key")?;
                    spills.push((p, key));
                }
                Message::TaskDone {
                    worker_id,
                    task_id,
                    spills,
                    bytes_read: d.u64("done.bytes_read")?,
                    bytes_written: d.u64("done.bytes_written")?,
                    micros: d.u64("done.micros")?,
                    tier_io: dec_tier_io(&mut d)?,
                }
            }
            TAG_TASK_FAIL => Message::TaskFail {
                worker_id: d.u64("fail.worker_id")?,
                task_id: d.u64("fail.task_id")?,
                error: d.str("fail.error")?,
            },
            other => {
                return Err(Error::wire(
                    WireKind::UnknownTag,
                    format!("tag {other:#04x}"),
                ))
            }
        };
        d.finish("message body")?;
        Ok(msg)
    }
}

fn crc_of(tag: u8, body: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(&[tag]);
    c.update(body);
    c.finish()
}

fn io_wire(kind: WireKind, e: std::io::Error) -> Error {
    Error::wire(kind, e.to_string())
}

/// Write one raw frame (`tag` + `body` + CRC trailer) to `w`.
pub fn write_frame(w: &mut dyn Write, tag: u8, body: &[u8]) -> Result<()> {
    if body.len() as u64 > MAX_FRAME as u64 {
        return Err(Error::wire(
            WireKind::Oversized,
            format!("refusing to send {} byte body (max {MAX_FRAME})", body.len()),
        ));
    }
    let mut header = [0u8; 5];
    header[..4].copy_from_slice(&(body.len() as u32).to_le_bytes());
    header[4] = tag;
    w.write_all(&header)
        .and_then(|_| w.write_all(body))
        .and_then(|_| w.write_all(&crc_of(tag, body).to_le_bytes()))
        .and_then(|_| w.flush())
        .map_err(|e| io_wire(WireKind::Closed, e))
}

/// Read exactly `buf.len()` bytes; `Ok(false)` on clean EOF before the
/// first byte, [`WireKind::Truncated`] on EOF mid-buffer.
fn read_exact_or_eof(r: &mut dyn Read, buf: &mut [u8], what: &str) -> Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(false);
                }
                return Err(Error::wire(
                    WireKind::Truncated,
                    format!("eof after {got} bytes of {what}"),
                ));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_wire(WireKind::Truncated, e)),
        }
    }
    Ok(true)
}

/// Read one raw frame. `Ok(None)` means the stream closed cleanly at a
/// frame boundary; every other shortfall is a typed [`Error::Wire`].
pub fn read_frame(r: &mut dyn Read) -> Result<Option<(u8, Vec<u8>)>> {
    let mut header = [0u8; 5];
    if !read_exact_or_eof(r, &mut header, "frame header")? {
        return Ok(None);
    }
    let len = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    if len > MAX_FRAME {
        return Err(Error::wire(
            WireKind::Oversized,
            format!("length prefix {len} exceeds max {MAX_FRAME}"),
        ));
    }
    let tag = header[4];
    let mut body = vec![0u8; len as usize];
    if !body.is_empty() && !read_exact_or_eof(r, &mut body, "frame body")? {
        return Err(Error::wire(WireKind::Truncated, "eof before frame body"));
    }
    let mut trailer = [0u8; 4];
    if !read_exact_or_eof(r, &mut trailer, "frame crc")? {
        return Err(Error::wire(WireKind::Truncated, "eof before frame crc"));
    }
    let stored = u32::from_le_bytes(trailer);
    let computed = crc_of(tag, &body);
    if stored != computed {
        return Err(Error::wire(
            WireKind::Crc,
            format!("stored {stored:#010x}, computed {computed:#010x}"),
        ));
    }
    Ok(Some((tag, body)))
}

/// Encode and frame one [`Message`] onto `w`.
pub fn write_message(w: &mut dyn Write, msg: &Message) -> Result<()> {
    let (tag, body) = msg.encode();
    write_frame(w, tag, &body)
}

/// Read and decode one [`Message`]; `Ok(None)` on clean EOF between
/// frames.
pub fn read_message(r: &mut dyn Read) -> Result<Option<Message>> {
    match read_frame(r)? {
        None => Ok(None),
        Some((tag, body)) => Message::decode(tag, &body).map(Some),
    }
}

/// Serialize a message to its full on-wire frame bytes (tests and the
/// loopback transport's byte-exactness checks).
pub fn frame_bytes(msg: &Message) -> Vec<u8> {
    let (tag, body) = msg.encode();
    let mut out = Vec::with_capacity(9 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.push(tag);
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc_of(tag, &body).to_le_bytes());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<Message> {
        vec![
            Message::Hello {
                version: WIRE_VERSION,
                role: Role::Worker,
                epoch: 7,
            },
            Message::HelloAck {
                version: WIRE_VERSION,
                epoch: 7,
                worker_id: 3,
            },
            Message::Put {
                key: "a/b".into(),
                data: vec![1, 2, 3],
            },
            Message::GetRange {
                key: "k".into(),
                offset: 100,
                len: 64,
            },
            Message::Stat { key: "k".into() },
            Message::Delete { key: "k".into() },
            Message::List { prefix: "p/".into() },
            Message::Get { key: "k".into() },
            Message::Rename {
                from: "k#s0.tmp-7".into(),
                to: "k#s0".into(),
            },
            Message::OkUnit,
            Message::OkBytes { data: vec![9; 10] },
            Message::OkMeta { size: 42 },
            Message::OkKeys {
                keys: vec!["a".into(), "b".into()],
            },
            Message::ErrReply {
                code: 1,
                msg: "missing".into(),
            },
            Message::Heartbeat { worker_id: 2 },
            Message::HeartbeatAck,
            Message::ReqTask { worker_id: 2 },
            Message::TaskAssign(TaskSpec {
                task_id: 11,
                job_id: "job-e1-x".into(),
                attempt: 2,
                preferred_node: Some(1),
                kind: TaskKind::Map {
                    object: "in/part-m-00000".into(),
                    offset: 0,
                    len: 1000,
                    task_index: 0,
                    partitions: 4,
                    bucket_map: (0..256).map(|b| b / 64).collect(),
                    shuffle_prefix: ".shuffle/job-e1-x/".into(),
                },
            }),
            Message::TaskAssign(TaskSpec {
                task_id: 12,
                job_id: "j".into(),
                attempt: 1,
                preferred_node: None,
                kind: TaskKind::Reduce {
                    partition: 3,
                    spill_keys: vec!["s1".into(), "s2".into()],
                    out_key: "out/part-r-00003".into(),
                },
            }),
            Message::NoTask {
                failed: true,
                msg: "all workers lost".into(),
            },
            Message::TaskDone {
                worker_id: 1,
                task_id: 11,
                spills: vec![(0, "sa".into()), (3, "sb".into())],
                bytes_read: 1000,
                bytes_written: 900,
                micros: 1234,
                tier_io: TierIo::default(),
            },
            Message::TaskDone {
                worker_id: 2,
                task_id: 12,
                spills: vec![],
                bytes_read: 4096,
                bytes_written: 4096,
                micros: 999,
                tier_io: TierIo {
                    mem_read_bytes: 2048,
                    mem_read_micros: 10,
                    remote_read_bytes: 2048,
                    remote_read_micros: 400,
                    mem_write_bytes: 4096,
                    mem_write_micros: 20,
                    remote_write_bytes: 4096,
                    remote_write_micros: 500,
                    wall_micros: 999,
                },
            },
            Message::TaskFail {
                worker_id: 1,
                task_id: 11,
                error: "injected fault: boom".into(),
            },
        ]
    }

    #[test]
    fn every_message_round_trips() {
        for msg in samples() {
            let bytes = frame_bytes(&msg);
            let mut cur = std::io::Cursor::new(bytes);
            let back = read_message(&mut cur).unwrap().unwrap();
            assert_eq!(back, msg);
            // and the stream is now cleanly at EOF
            assert!(read_message(&mut cur).unwrap().is_none());
        }
    }

    #[test]
    fn frame_layout_is_len_tag_body_crc() {
        let msg = Message::OkMeta { size: 0x0102_0304 };
        let bytes = frame_bytes(&msg);
        // body = 8-byte LE size
        assert_eq!(bytes.len(), 4 + 1 + 8 + 4);
        assert_eq!(&bytes[..4], &8u32.to_le_bytes());
        assert_eq!(bytes[4], TAG_OK_META);
        assert_eq!(&bytes[5..13], &0x0102_0304u64.to_le_bytes());
        let crc = u32::from_le_bytes(bytes[13..17].try_into().unwrap());
        assert_eq!(crc, crc_of(TAG_OK_META, &bytes[5..13]));
    }

    #[test]
    fn truncated_stream_is_typed() {
        let bytes = frame_bytes(&Message::Heartbeat { worker_id: 5 });
        for cut in 1..bytes.len() {
            let mut cur = std::io::Cursor::new(bytes[..cut].to_vec());
            let err = read_message(&mut cur).unwrap_err();
            assert!(
                matches!(
                    err,
                    Error::Wire {
                        kind: WireKind::Truncated,
                        ..
                    }
                ),
                "cut={cut} gave {err}"
            );
        }
    }

    #[test]
    fn crc_flip_is_typed() {
        let mut bytes = frame_bytes(&Message::OkBytes {
            data: vec![7; 100],
        });
        // flip one bit in the body
        bytes[20] ^= 0x10;
        let err = read_message(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(
            err,
            Error::Wire {
                kind: WireKind::Crc,
                ..
            }
        ));
    }

    #[test]
    fn tag_is_crc_covered() {
        let mut bytes = frame_bytes(&Message::OkUnit);
        bytes[4] = TAG_HEARTBEAT_ACK; // valid other tag, same (empty) body
        let err = read_message(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(
            err,
            Error::Wire {
                kind: WireKind::Crc,
                ..
            }
        ));
    }

    #[test]
    fn oversized_length_is_typed_and_does_not_allocate() {
        let mut bytes = vec![];
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.push(TAG_OK_UNIT);
        let err = read_message(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(
            err,
            Error::Wire {
                kind: WireKind::Oversized,
                ..
            }
        ));
    }

    #[test]
    fn unknown_tag_with_valid_crc_is_typed() {
        let tag = 0xEE;
        let body = b"whatever";
        let mut bytes = vec![];
        bytes.extend_from_slice(&(body.len() as u32).to_le_bytes());
        bytes.push(tag);
        bytes.extend_from_slice(body);
        bytes.extend_from_slice(&crc_of(tag, body).to_le_bytes());
        let err = read_message(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(
            err,
            Error::Wire {
                kind: WireKind::UnknownTag,
                ..
            }
        ));
    }

    #[test]
    fn trailing_bytes_in_body_are_malformed() {
        let tag = TAG_OK_META;
        let mut body = 9u64.to_le_bytes().to_vec();
        body.push(0xFF); // one byte too many
        let mut bytes = vec![];
        bytes.extend_from_slice(&(body.len() as u32).to_le_bytes());
        bytes.push(tag);
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&crc_of(tag, &body).to_le_bytes());
        let err = read_message(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert!(matches!(
            err,
            Error::Wire {
                kind: WireKind::Malformed,
                ..
            }
        ));
    }

    #[test]
    fn back_to_back_frames_stream() {
        let msgs = samples();
        let mut stream = vec![];
        for m in &msgs {
            stream.extend_from_slice(&frame_bytes(m));
        }
        let mut cur = std::io::Cursor::new(stream);
        for m in &msgs {
            assert_eq!(&read_message(&mut cur).unwrap().unwrap(), m);
        }
        assert!(read_message(&mut cur).unwrap().is_none());
    }
}
