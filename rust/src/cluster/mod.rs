//! The cluster plane: multi-process TeraSort over a hand-rolled,
//! std-only TCP protocol.
//!
//! Everything the single-process engine does in one address space —
//! store, scheduler, map/reduce execution — splits here into three
//! process roles connected by length-prefixed, CRC-trailered frames
//! ([`wire`]):
//!
//! - **PFS stripe servers** ([`remote::serve`]) expose a local
//!   [`ObjectStore`](crate::storage::ObjectStore) over the wire; the
//!   [`remote::RemotePfs`] client stripes every object round-robin
//!   across them, mirroring the in-process
//!   [`Pfs`](crate::storage::pfs::Pfs) layout.
//! - The **coordinator** ([`coordinator::Coordinator`]) plans splits
//!   with the same locality scheduler as the job server, dispatches
//!   [`wire::TaskSpec`]s to pulling workers, tracks heartbeats
//!   ([`heartbeat`]), and re-executes tasks stranded on dead workers.
//! - **Workers** ([`worker::Worker`]) pull tasks, sort splits with the
//!   shared [`SortKernel`](crate::terasort::SortKernel), spill through
//!   the shared store's `.shuffle/` namespace, and k-way merge reduce
//!   output.
//!
//! All roles are wired to [`transport::Transport`], which has a real
//! TCP implementation and a deterministic in-process loopback with
//! scriptable faults — the chaos tests run the full cluster, kills
//! included, inside one `cargo test` process with no sockets and no
//! sleeps. `tlstore cluster {coordinator,worker,pfs-server}` runs the
//! same code as real OS processes.

/// Leader side: job intake, task assignment, worker registry.
pub mod coordinator;
/// Liveness tracking and dead-worker reassignment.
pub mod heartbeat;
/// Client handle for driving a remote coordinator.
pub mod remote;
/// Length-prefixed TCP framing shared by both ends.
pub mod transport;
/// Message encode/decode (the `Enc`/`Dec` pair).
pub mod wire;
/// Worker side: task execution loop.
pub mod worker;

pub use coordinator::{
    ClusterJob, ClusterReport, Coordinator, CoordinatorConfig, TaskBoard, Ticker, WorkerIo,
    MAX_TASK_ATTEMPTS,
};
pub use heartbeat::{Clock, ManualClock, SystemClock, WorkerRegistry};
pub use remote::{serve, RemotePfs, DEFAULT_STRIPE_SIZE, MAX_STRIPE_SIZE};
pub use transport::{Conn, FaultScript, Listener, LoopbackNet, TcpTransport, Transport};
pub use wire::{Message, Role, TaskKind, TaskSpec, TierIo, WIRE_VERSION};
pub use worker::{Worker, WorkerSummary};
