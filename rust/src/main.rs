//! `tlstore` — the command-line launcher.
//!
//! ```text
//! tlstore info
//! tlstore teragen   --root DIR --backend tls|pfs|hdfs --records N
//! tlstore terasort  --root DIR --backend tls|pfs|hdfs --reducers R
//! tlstore validate  --root DIR --backend tls|pfs|hdfs
//! tlstore job submit    --workload wordcount-topk|log-sessions [--jobs N]
//! tlstore job status    --root DIR       (shuffle residue of a crashed root)
//! tlstore job workloads                  (list built-in pipelines)
//! tlstore cluster pfs-server  --listen ADDR --root DIR
//! tlstore cluster coordinator --listen ADDR --workers N [--pfs a,b] [--config cluster.toml]
//! tlstore cluster worker      --coordinator ADDR [--pfs a,b] [--mem-capacity N] [--die-after-tasks N]
//! tlstore bench parity  [--smoke] [--tolerance X] [--out-dir DIR]
//! tlstore model     [--pfs-aggregate MB/s] [--f 0.2]      (Figure 5)
//! tlstore sim       [--backend ...] [--nodes N] [--data-nodes M] (Figure 7)
//! tlstore mountain                                        (Figure 6, sim)
//! ```
//!
//! Storage roots persist between invocations: `teragen`, `terasort`, and
//! `validate` compose into the paper's §5.3 pipeline. `job submit` drives
//! named multi-stage pipelines through the [`tlstore::mapreduce::JobServer`],
//! spilling every shuffle through the store's `.shuffle/` namespace.

#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::path::PathBuf;
use std::sync::Arc;

use tlstore::bench::parity::ParityRunOptions;
use tlstore::cli::Args;
use tlstore::cluster::{
    serve, ClusterJob, Conn, Coordinator, CoordinatorConfig, Listener, RemotePfs, TcpTransport,
    Transport, Worker,
};
use tlstore::config::presets;
use tlstore::config::Backend;
use tlstore::error::{Error, Result};
use tlstore::mapreduce::{Engine, JobServer, JobServerConfig};
use tlstore::model::CaseStudyParams;
use tlstore::runtime::Runtime;
use tlstore::sim::{simulate_terasort, BackendKind, SimConstants};
use tlstore::storage::fault::{FaultPlan, FaultStore};
use tlstore::storage::hdfs::HdfsLike;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, Recover, RecoveryReport};
use tlstore::terasort::{self, SortKernel};
use tlstore::testing::parity::ParityConfig;

fn open_tls(args: &Args, root: &std::path::Path, servers: usize) -> Result<TwoLevelStore> {
    let cfg = TlsConfig::builder(root)
        .mem_capacity(args.get_bytes("mem-capacity", 256 << 20)?)
        .block_size(args.get_bytes("block-size", 4 << 20)?)
        .stripe_size(args.get_bytes("stripe-size", 1 << 20)?)
        .pfs_servers(servers)
        .eviction(&args.get("eviction", "lru"))
        .mem_shards(args.get_parse(
            "mem-shards",
            presets::tuning::default_mem_shards(),
        )?)
        .concurrent_writethrough(!args.has("sequential-writethrough"))
        .append_coalesce(args.get_bytes("append-coalesce", 0)? as usize)
        .build()?;
    TwoLevelStore::open(cfg)
}

fn open_store(args: &Args) -> Result<Arc<dyn ObjectStore>> {
    let backend = Backend::parse(&args.get("backend", "tls"))?;
    let root = PathBuf::from(args.get("root", "/tmp/tlstore"));
    let servers = args.get_parse("pfs-servers", 4usize)?;
    let coalesce = args.get_bytes("append-coalesce", 0)? as usize;
    let store: Arc<dyn ObjectStore> = match backend {
        Backend::TwoLevel => Arc::new(open_tls(args, &root, servers)?),
        Backend::Pfs => {
            let mut pfs = Pfs::open(&root, servers, args.get_bytes("stripe-size", 1 << 20)?)?;
            pfs.append_coalesce = coalesce;
            Arc::new(pfs)
        }
        Backend::Hdfs => {
            let mut hdfs = HdfsLike::open(
                &root,
                args.get_parse("nodes", 4usize)?,
                args.get_parse("replication", 3usize)?,
            )?;
            hdfs.append_coalesce = coalesce;
            Arc::new(hdfs)
        }
    };
    // fault-injection harness: wrap the store so the plan's triggers fire
    // on the real API surface (crash-recovery drills, robustness demos)
    let spec = args.get("fault-plan", "");
    Ok(if spec.is_empty() {
        store
    } else {
        Arc::new(FaultStore::new(store, FaultPlan::parse(&spec)?))
    })
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    println!("tlstore — two-level storage for big-data analytics on HPC");
    println!("paper: Xuan et al., 2015 (DOI 10.1145/2831244.2831253)\n");
    match Runtime::load_dir(std::path::Path::new("artifacts")) {
        Ok(rt) => {
            println!("PJRT platform : {}", rt.platform());
            for name in rt.names() {
                let a = rt.artifact(name)?;
                println!(
                    "artifact      : {name}  in={:?} out={:?}",
                    a.spec.inputs.iter().map(|t| t.render()).collect::<Vec<_>>(),
                    a.spec.outputs.iter().map(|t| t.render()).collect::<Vec<_>>(),
                );
            }
        }
        Err(e) => println!("artifacts     : not loaded ({e}) — run `make artifacts`"),
    }
    println!("\nTable 1 (paper testbeds):");
    println!("{:<10} {:>10} {:>8} {:>12} {:>6}", "system", "disk GB", "RAM GB", "PFS GB", "cores");
    for s in presets::TABLE1 {
        println!(
            "{:<10} {:>10.0} {:>8.0} {:>12.0} {:>6}",
            s.name, s.local_disk_gb, s.ram_gb, s.pfs_gb, s.cpu_cores
        );
    }
    Ok(())
}

fn cmd_teragen(args: &Args) -> Result<()> {
    let store = open_store(args)?;
    let records = args.get_parse("records", 100_000u64)?;
    let per_object = args.get_parse("records-per-object", 25_000u64)?;
    let seed = args.get_parse("seed", 42u64)?;
    let prefix = args.get("prefix", "in/");
    args.finish()?;
    let (result, _dt) = tlstore::bench::run_named(
        &format!("teragen {records} records → {} ({})", prefix, store.kind()),
        Some(records * terasort::RECORD_SIZE as u64),
        || terasort::teragen(store.as_ref(), &prefix, records, per_object, seed),
    );
    // surface generation failures (previously swallowed: an injected
    // fault or full disk exited 0 with no data written)
    result?;
    Ok(())
}

fn cmd_terasort(args: &Args) -> Result<()> {
    let store = open_store(args)?;
    // kernel-backed sort when artifacts are present, CPU sort otherwise —
    // TeraSort always runs now, on every backend
    let kernel = SortKernel::auto(std::path::Path::new(&args.get("artifacts", "artifacts")));
    let reducers = args.get_parse("reducers", 4u32)?;
    let split = args.get_bytes("split-size", 8 << 20)?;
    let workers = args.get_parse("workers", 0usize)?;
    let overlap_depth = args.get_parse("overlap-depth", 0usize)?;
    let in_prefix = args.get("prefix", "in/");
    let out_prefix = args.get("out", "out/");
    args.finish()?;
    let workers = if workers == 0 {
        JobServerConfig::default().workers
    } else {
        workers
    };
    let server = JobServer::new(
        Arc::clone(&store),
        JobServerConfig {
            workers,
            containers_per_node: workers,
            max_concurrent_jobs: 1,
            overlap_depth,
            ..JobServerConfig::default()
        },
    );
    println!("sort kernel: {}", kernel.name());
    let stats = terasort::run_terasort(
        &server,
        kernel,
        &in_prefix,
        &out_prefix,
        reducers,
        split,
        true,
    )?;
    // the v1 collapse keeps the familiar one-line shape; the measured
    // line is the I/O-busy-time view the parity harness gates on
    let js = stats.to_job_stats();
    println!("{}", js.report());
    println!(
        "measured I/O: map read {:.1} MB/s, reduce write {:.1} MB/s (busy-time)",
        js.measured_read_mbs(),
        js.measured_write_mbs()
    );
    server.shutdown()?;
    Ok(())
}

/// `tlstore bench parity [--smoke]` — run the model-parity harness and
/// emit `BENCH_fig7.json` / `BENCH_fig5.json` (see `bench::parity`).
/// `tlstore bench overlap [--smoke]` — A/B the overlap knobs and emit
/// `BENCH_overlap.json` (see `bench::overlap`).
fn cmd_bench(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("overlap") => {
            let opts = tlstore::bench::overlap::OverlapRunOptions {
                smoke: args.has("smoke"),
                out_dir: std::path::PathBuf::from(args.get("out-dir", ".")),
            };
            args.finish()?;
            return tlstore::bench::overlap::run(&opts);
        }
        Some("parity") | None => {}
        Some(other) => {
            return Err(Error::InvalidArg(format!(
                "unknown bench subcommand `{other}` (try: parity|overlap)"
            )))
        }
    }
    let smoke = args.has("smoke");
    let mut cfg = if smoke {
        ParityConfig::smoke()
    } else {
        ParityConfig::default()
    };
    // a --config TOML supplies the store geometry and (outside --smoke,
    // whose wide band is the point) the parity_tolerance knob; an
    // explicit --tolerance flag beats both
    let config_path = args.get("config", "");
    if !config_path.is_empty() {
        let engine_cfg =
            tlstore::config::EngineConfig::from_file(std::path::Path::new(&config_path))?;
        if !smoke {
            cfg.tolerance = engine_cfg.parity_tolerance;
        }
        cfg.mem_capacity = engine_cfg.mem_capacity;
        cfg.block_size = engine_cfg.block_size;
        cfg.pfs_servers = engine_cfg.pfs_servers;
        cfg.stripe_size = engine_cfg.stripe_size;
    }
    cfg.records = args.get_parse("records", cfg.records)?;
    cfg.scale = args.get_parse("scale", cfg.scale)?;
    cfg.reducers = args.get_parse("reducers", cfg.reducers)?;
    cfg.split_size = args.get_bytes("split-size", cfg.split_size)?;
    cfg.tolerance = args.get_parse("tolerance", cfg.tolerance)?;
    cfg.seed = args.get_parse("seed", cfg.seed)?;
    let out_dir = std::path::PathBuf::from(args.get("out-dir", "."));
    args.finish()?;
    if cfg.tolerance <= 0.0 {
        return Err(Error::InvalidArg(format!(
            "--tolerance must be positive, got {}",
            cfg.tolerance
        )));
    }
    tlstore::bench::parity::run(&ParityRunOptions { cfg, out_dir })?;
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let store = open_store(args)?;
    let out_prefix = args.get("out", "out/");
    let in_prefix = args.get("prefix", "in/");
    args.finish()?;
    let report = terasort::teravalidate(store.as_ref(), &out_prefix)?;
    let (in_records, in_sum) = terasort::input_checksum(store.as_ref(), &in_prefix)?;
    println!(
        "records={} sorted={} checksum_match={}",
        report.records,
        report.sorted,
        report.records == in_records && report.checksum == in_sum
    );
    if !report.sorted || report.records != in_records || report.checksum != in_sum {
        return Err(Error::Job("teravalidate FAILED".into()));
    }
    Ok(())
}

fn cmd_model(args: &Args) -> Result<()> {
    let b = args.get_parse("pfs-aggregate", 10_000.0f64)?;
    args.finish()?;
    let m = CaseStudyParams::new(b);
    println!("Figure 5 case study @ PFS aggregate {:.0} MB/s", b);
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14} {:>14}",
        "N", "hdfs_read", "pfs_read", "tls_read(0.2)", "tls_read(0.5)", "hdfs_write"
    );
    for n in [1u32, 8, 16, 32, 43, 53, 64, 83, 128, 211, 259, 262, 414, 512, 1024, 1294] {
        println!(
            "{:>6} {:>14.0} {:>14.0} {:>14.0} {:>14.0} {:>14.0}",
            n,
            m.hdfs_read_aggregate(n),
            m.pfs_aggregate_throughput(n),
            m.tls_read_aggregate(n, 0.2),
            m.tls_read_aggregate(n, 0.5),
            m.hdfs_write_aggregate(n),
        );
    }
    println!(
        "\ncrossovers: read vs pfs N={}  vs tls(f=0.2) N={}  vs tls(f=0.5) N={}  write N={}",
        m.crossover_read_vs_pfs(),
        m.crossover_read_vs_tls(0.2),
        m.crossover_read_vs_tls(0.5),
        m.crossover_write()
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let n = args.get_parse("nodes", 16usize)?;
    let m = args.get_parse("data-nodes", 2usize)?;
    let containers = args.get_parse("containers", 16usize)?;
    let input_gb = args.get_parse("input-gb", 16.0f64)?;
    let backend = match args.get("backend", "all").as_str() {
        "hdfs" => vec![BackendKind::Hdfs],
        "ofs" | "pfs" => vec![BackendKind::Ofs],
        "tls" => vec![BackendKind::Tls { f_pct: 100 }],
        "all" => vec![
            BackendKind::Hdfs,
            BackendKind::Ofs,
            BackendKind::Tls { f_pct: 100 },
        ],
        other => return Err(Error::InvalidArg(format!("unknown backend {other}"))),
    };
    let show_timelines = args.has("timelines");
    args.finish()?;
    println!(
        "TeraSort simulation: {n} compute × {containers} containers, {m} data nodes, {input_gb} GB"
    );
    for b in backend {
        let r = simulate_terasort(b, n, m, containers, input_gb, SimConstants::default())?;
        println!(
            "{:<12} map={:>8.1}s  reduce={:>8.1}s  total={:>8.1}s",
            r.backend,
            r.map_time,
            r.reduce_time,
            r.total()
        );
        if show_timelines {
            println!("-- map phase utilization --");
            print!("{}", r.result_map.timelines.render(48));
            println!("-- reduce phase utilization --");
            print!("{}", r.result_reduce.timelines.render(48));
        }
    }
    Ok(())
}

fn cmd_analytics(args: &Args) -> Result<()> {
    let store = open_store(args)?;
    let runtime = Arc::new(Runtime::load_dir(std::path::Path::new(
        &args.get("artifacts", "artifacts"),
    ))?);
    let tables = args.get_parse("tables", 8u32)?;
    let rows = args.get_parse("rows", 6000usize)?;
    let reducers = args.get_parse("reducers", 4u32)?;
    let generate = !args.has("no-generate");
    args.finish()?;

    if generate {
        tlstore::analytics::generate_tables(store.as_ref(), "events/", tables, rows, 7)?;
        println!("generated {tables} tables × {rows} rows into {}", store.kind());
    }
    let engine = Engine::local();
    let stats = tlstore::analytics::run_analytics(
        &engine,
        Arc::clone(&store),
        runtime,
        "events/",
        "stats/",
        reducers,
    )?;
    println!("{}", stats.report());
    for key in store.list("stats/") {
        print!("{}", String::from_utf8_lossy(&store.read(&key)?));
    }
    Ok(())
}

/// `tlstore job <submit|status|workloads>` — the Job API v2 surface.
fn cmd_job(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("submit") => cmd_job_submit(args),
        Some("status") => cmd_job_status(args),
        Some("workloads") | None => {
            args.finish()?;
            println!("built-in workloads (tlstore job submit --workload NAME):");
            for w in tlstore::workloads::NamedWorkload::all() {
                println!("  {:<16} {}", w.name(), w.description());
            }
            Ok(())
        }
        Some(other) => Err(Error::InvalidArg(format!(
            "unknown job subcommand `{other}` (submit|status|workloads)"
        ))),
    }
}

/// Generate, submit, watch, and verify one or more named pipelines.
///
/// Two sizing paths: `--config engine.toml` loads an
/// [`tlstore::config::EngineConfig`] and derives both the two-level
/// store and the server knobs from it (`max_concurrent_jobs`,
/// `shuffle_spill_threshold`, `shuffle_chunk` flow from `[engine]`);
/// otherwise the storage/server flags apply individually.
fn cmd_job_submit(args: &Args) -> Result<()> {
    let workload = tlstore::workloads::NamedWorkload::parse(&args.get("workload", "wordcount-topk"))?;
    let jobs = args.get_parse("jobs", 1usize)?.max(1);
    let scale = args.get_parse("scale", 8u64)?;
    let seed = args.get_parse("seed", 42u64)?;
    let reducers = args.get_parse("reducers", 4u32)?;
    let config_path = args.get("config", "");
    let (store, cfg): (Arc<dyn ObjectStore>, JobServerConfig) = if config_path.is_empty() {
        let store = open_store(args)?;
        let workers = match args.get_parse("workers", 0usize)? {
            0 => JobServerConfig::default().workers,
            n => n,
        };
        let cfg = JobServerConfig {
            workers,
            containers_per_node: workers,
            max_concurrent_jobs: args.get_parse(
                "max-jobs",
                presets::tuning::default_max_concurrent_jobs(
                    args.get_bytes("mem-capacity", 256 << 20)?,
                ),
            )?,
            shuffle_spill_threshold: args.get_bytes("spill-threshold", 0)?,
            shuffle_chunk: args.get_bytes("shuffle-chunk", 1 << 20)? as usize,
            overlap_depth: args.get_parse("overlap-depth", 0usize)?,
            ..JobServerConfig::default()
        };
        (store, cfg)
    } else {
        let engine_cfg = tlstore::config::EngineConfig::from_file(std::path::Path::new(&config_path))?;
        let store: Arc<dyn ObjectStore> = Arc::new(TwoLevelStore::open(
            tlstore::storage::tls::TlsConfig::from_engine(&engine_cfg),
        )?);
        (store, JobServerConfig::from_engine(&engine_cfg))
    };
    args.finish()?;

    let server = JobServer::new(Arc::clone(&store), cfg);
    let mut handles = Vec::new();
    for j in 0..jobs {
        // one namespace per submission so concurrent jobs stay isolated
        let root = format!("jobs/{}-{j}/", workload.name());
        let bytes = workload.generate(store.as_ref(), &root, scale, seed ^ j as u64)?;
        println!("generated {bytes} input bytes under {root}in/");
        let handle = server.submit(workload.pipeline(&root, reducers)?)?;
        println!("submitted {} as {}", handle.name(), handle.id());
        handles.push((root, handle));
    }
    // watch until every job is terminal
    loop {
        let mut all_done = true;
        for (_, h) in &handles {
            let status = h.status();
            if !status.is_terminal() {
                all_done = false;
            }
            let p = h.progress();
            println!(
                "  {}: {:?} stage {}/{} tasks {}/{}",
                h.id(),
                status,
                p.stage.min(p.stages),
                p.stages,
                p.tasks_done,
                p.tasks_total
            );
        }
        if all_done {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    let mut failed = 0;
    for (root, h) in &handles {
        match h.join() {
            Ok(stats) => {
                println!("{}", stats.report());
                println!("verify: {}", workload.verify(store.as_ref(), root)?);
            }
            Err(e) => {
                failed += 1;
                eprintln!("{}: {e}", h.id());
            }
        }
    }
    server.shutdown()?;
    if failed > 0 {
        return Err(Error::Job(format!("{failed} job(s) failed")));
    }
    println!(
        "shuffle namespace clean: {}",
        store.list(tlstore::storage::SHUFFLE_NS).is_empty()
    );
    Ok(())
}

/// Inspect `.shuffle/` residue of a (possibly crashed) root.
fn cmd_job_status(args: &Args) -> Result<()> {
    let store = open_store(args)?;
    args.finish()?;
    let residue = store.list(tlstore::storage::SHUFFLE_NS);
    if residue.is_empty() {
        println!("no shuffle residue: no job is mid-flight in this root");
        return Ok(());
    }
    let mut per_job: std::collections::BTreeMap<&str, (usize, u64)> = Default::default();
    for key in &residue {
        let job = key[tlstore::storage::SHUFFLE_NS.len()..]
            .split('/')
            .next()
            .unwrap_or("?");
        let e = per_job.entry(job).or_default();
        e.0 += 1;
        e.1 += store.size(key).unwrap_or(0);
    }
    println!("shuffle residue ({} objects) — a job crashed mid-flight:", residue.len());
    for (job, (objects, bytes)) in per_job {
        println!("  {job}: {objects} objects, {bytes} bytes");
    }
    println!("run `tlstore recover` on this root to reap it");
    Ok(())
}

/// `tlstore cluster <coordinator|worker|pfs-server>` — the multi-process
/// cluster plane ([`tlstore::cluster`]): PFS stripe servers export a
/// store over TCP, workers pull map/reduce tasks, the coordinator
/// schedules with locality and re-executes tasks stranded on dead
/// workers.
fn cmd_cluster(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("coordinator") => cmd_cluster_coordinator(args),
        Some("worker") => cmd_cluster_worker(args),
        Some("pfs-server") => cmd_cluster_pfs_server(args),
        other => Err(Error::InvalidArg(format!(
            "unknown cluster subcommand {other:?} (coordinator|worker|pfs-server)"
        ))),
    }
}

/// Parse a comma-separated `--pfs a:1,b:2` address list.
fn pfs_addrs(args: &Args) -> Vec<String> {
    args.get("pfs", "")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// The shared store a cluster role executes against: a [`RemotePfs`]
/// client when `--pfs` names stripe servers, otherwise a locally
/// attached backend (`--backend`/`--root`, shared via the filesystem).
fn cluster_store(args: &Args, stripe: u64) -> Result<Arc<dyn ObjectStore>> {
    let addrs = pfs_addrs(args);
    if addrs.is_empty() {
        open_store(args)
    } else {
        Ok(Arc::new(RemotePfs::connect(&TcpTransport, &addrs, stripe)?))
    }
}

/// Dial the coordinator, retrying while it boots.
fn connect_retry(addr: &str, attempts: u32) -> Result<Box<dyn Conn>> {
    let mut last = None;
    for i in 0..attempts.max(1) {
        match TcpTransport.connect(addr) {
            Ok(c) => return Ok(c),
            Err(e) => last = Some(e),
        }
        if i + 1 < attempts {
            std::thread::sleep(std::time::Duration::from_millis(200));
        }
    }
    Err(last.unwrap())
}

/// Serve a local store's objects to [`RemotePfs`] clients until killed.
fn cmd_cluster_pfs_server(args: &Args) -> Result<()> {
    let listen = args.get("listen", "127.0.0.1:0");
    let root = PathBuf::from(args.get("root", "/tmp/tlstore-pfs"));
    let dirs = args.get_parse("pfs-servers", 1usize)?;
    let stripe = args.get_bytes("stripe-size", 1 << 20)?;
    args.finish()?;
    let store: Arc<dyn ObjectStore> = Arc::new(Pfs::open(&root, dirs, stripe)?);
    let listener: Arc<dyn Listener> = Arc::from(TcpTransport.listen(&listen)?);
    // the harness parses this line for the ephemeral port — keep it first
    println!("pfs-server listening on {}", listener.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    serve(listener, store)
}

/// Pull and execute tasks until the coordinator dismisses this worker.
/// `--mem-capacity N` (or `[cluster] worker_mem_capacity` with
/// `--config`) layers the paper's process-local memory tier over the
/// `--pfs` stripe servers; `0` (the default) runs untiered.
fn cmd_cluster_worker(args: &Args) -> Result<()> {
    let coord = args.get("coordinator", "127.0.0.1:7000");
    let topo = {
        let path = args.get("config", "");
        if path.is_empty() {
            tlstore::config::ClusterTopology::default()
        } else {
            tlstore::config::ClusterTopology::from_file(std::path::Path::new(&path))?
        }
    };
    let stripe = args.get_bytes("stripe-size", topo.stripe_size)?;
    let mem_cap = args.get_bytes("mem-capacity", topo.worker_mem_capacity)?;
    let block = args.get_bytes("block-size", 4 << 20)?;
    let die_after = args.get_parse("die-after-tasks", 0u64)?;
    let artifacts = args.get("artifacts", "artifacts");
    let kernel_path = std::path::PathBuf::from(&artifacts);
    let mut addrs = pfs_addrs(args);
    if addrs.is_empty() {
        addrs = topo.pfs.clone();
    }
    // Tiered only over remote stripe servers; with a locally attached
    // backend, `--mem-capacity` keeps its old meaning (the local
    // store's own memory-tier capacity, via `open_store`).
    let mut worker = if mem_cap > 0 && !addrs.is_empty() {
        let remote = RemotePfs::connect(&TcpTransport, &addrs, stripe)?;
        let tls_cfg = TlsConfig::builder("worker-mem-tier")
            .mem_capacity(mem_cap)
            .block_size(block)
            .build()?;
        args.finish()?;
        let store = Arc::new(TwoLevelStore::with_tier(tls_cfg, remote)?);
        Worker::tiered(store, SortKernel::auto(&kernel_path))
    } else {
        let store = cluster_store(args, stripe)?;
        args.finish()?;
        Worker::new(store, SortKernel::auto(&kernel_path))
    };
    if die_after > 0 {
        worker = worker.die_after_assignments(die_after);
    }
    let conn = connect_retry(&coord, 50)?;
    let summary = worker.run(conn)?;
    println!(
        "worker {}: {} task(s) done{}",
        summary.worker_id,
        summary.tasks_done,
        if summary.died { ", died (injected)" } else { "" }
    );
    if let Some(msg) = summary.job_failed {
        println!("job failed: {msg}");
    }
    Ok(())
}

/// Generate input (unless `--records 0`), wait for the workers, run one
/// distributed TeraSort, validate the output, and report re-execution
/// and per-worker I/O evidence.
fn cmd_cluster_coordinator(args: &Args) -> Result<()> {
    let mut topo = {
        let path = args.get("config", "");
        if path.is_empty() {
            tlstore::config::ClusterTopology::default()
        } else {
            tlstore::config::ClusterTopology::from_file(std::path::Path::new(&path))?
        }
    };
    let listen = args.get("listen", &topo.coordinator);
    topo.workers = args.get_parse("workers", topo.workers)?;
    topo.grace_ms = args.get_parse("grace-ms", topo.grace_ms)?;
    topo.heartbeat_ms = args.get_parse("heartbeat-ms", topo.heartbeat_ms)?;
    let flag_pfs = pfs_addrs(args);
    if !flag_pfs.is_empty() {
        topo.pfs = flag_pfs;
    }
    let stripe = args.get_bytes("stripe-size", topo.stripe_size)?;
    let epoch = match args.get_parse("epoch", topo.epoch)? {
        // 0 = derive a fresh epoch so successive incarnations never
        // collide in the shuffle namespace
        0 => {
            let nanos = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(1);
            nanos ^ u64::from(std::process::id())
        }
        e => e,
    };
    let records = args.get_parse("records", 100_000u64)?;
    let per_object = args.get_parse("records-per-object", 25_000u64)?;
    let reducers = args.get_parse("reducers", 4u32)?;
    let split_size = args.get_bytes("split-size", 1 << 20)?;
    let seed = args.get_parse("seed", 42u64)?;
    let sample_objects = args.get_parse("sample-objects", 2usize)?;
    let in_prefix = args.get("prefix", "in/");
    let out_prefix = args.get("out", "out/");
    let artifacts = args.get("artifacts", "artifacts");
    let store = if topo.pfs.is_empty() {
        open_store(args)?
    } else {
        Arc::new(RemotePfs::connect(&TcpTransport, &topo.pfs, stripe)?) as Arc<dyn ObjectStore>
    };
    args.finish()?;
    topo.validate()?;

    let kernel = SortKernel::auto(std::path::Path::new(&artifacts));
    let listener = TcpTransport.listen(&listen)?;
    // the harness parses this line for the ephemeral port — keep it first
    println!("coordinator listening on {}", listener.local_addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();

    if records > 0 {
        let written =
            terasort::teragen(store.as_ref(), &in_prefix, records, per_object, seed)?;
        println!("teragen: {records} records, {written} bytes under {in_prefix}");
        std::io::stdout().flush().ok();
    }

    let coord = Coordinator::new(
        listener,
        Arc::clone(&store),
        kernel,
        CoordinatorConfig {
            expected_workers: topo.workers,
            epoch,
            grace_ms: topo.grace_ms,
        },
    );
    let ticker = coord.ticker();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let tick_thread = {
        let stop = Arc::clone(&stop);
        let period = std::time::Duration::from_millis(topo.heartbeat_ms.max(1));
        std::thread::spawn(move || {
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                ticker.tick();
                std::thread::sleep(period);
            }
        })
    };
    let result = coord.run(&ClusterJob {
        name: "terasort".into(),
        input_prefix: in_prefix.clone(),
        output_prefix: out_prefix.clone(),
        reducers,
        split_size,
        sample_objects,
    });
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = tick_thread.join();
    let report = match result {
        Ok(r) => r,
        Err(e) => {
            coord.shutdown();
            return Err(e);
        }
    };
    coord.shutdown();
    println!(
        "job {} done: {} map + {} reduce tasks, workers seen {} lost {}, locality {}/{}",
        report.job_id,
        report.map_tasks,
        report.reduce_tasks,
        report.workers_seen,
        report.workers_lost,
        report.locality_hits,
        report.locality_total,
    );
    // the TCP smoke test greps this line for the re-execution evidence
    println!("re-executed tasks: {:?}", report.reexecuted);
    // present only when at least one worker ran tiered (--mem-capacity);
    // the TCP smoke test greps it to prove the mem tier saw hits
    if let Some(f) = report.observed_read_residency() {
        println!(
            "tier reads: mem {} B, remote {} B, residency {:.3}",
            report.mem_read_bytes(),
            report.remote_read_bytes(),
            f
        );
    }
    for (id, io) in &report.per_worker {
        if let Some(eff) = io.overlap_efficiency() {
            println!(
                "w{id} overlap: {:.2} busy-s/wall-s ({:.3} s storage busy over {:.3} s of tiered tasks)",
                eff,
                io.tier_busy_secs(),
                io.tier_wall_secs
            );
        }
    }
    let timelines = report.timelines();
    if !timelines.series.is_empty() {
        print!("{}", timelines.render(40));
    }
    let v = terasort::teravalidate(store.as_ref(), &out_prefix)?;
    println!(
        "validate: {} records, sorted={}, checksum={:#018x}",
        v.records, v.sorted, v.checksum
    );
    if !v.sorted || v.records == 0 {
        return Err(Error::Job(format!(
            "terasort output failed validation ({} records, sorted={})",
            v.records, v.sorted
        )));
    }
    Ok(())
}

fn cmd_recover(args: &Args) -> Result<()> {
    let backend = Backend::parse(&args.get("backend", "tls"))?;
    let root = PathBuf::from(args.get("root", "/tmp/tlstore"));
    let servers = args.get_parse("pfs-servers", 4usize)?;
    let report: RecoveryReport = match backend {
        Backend::TwoLevel => {
            let store = open_tls(args, &root, servers)?;
            args.finish()?;
            store.recover()?
        }
        Backend::Pfs => {
            let store = Pfs::open(&root, servers, args.get_bytes("stripe-size", 1 << 20)?)?;
            args.finish()?;
            Recover::recover(&store)?
        }
        Backend::Hdfs => {
            let store = HdfsLike::open(
                &root,
                args.get_parse("nodes", 4usize)?,
                args.get_parse("replication", 3usize)?,
            )?;
            args.finish()?;
            Recover::recover(&store)?
        }
    };
    println!("recover {} at {}: {report}", backend.name(), root.display());
    for key in &report.quarantined {
        println!("quarantined: {key}");
    }
    for key in &report.repaired {
        println!("repaired: {key}");
    }
    Ok(())
}

fn cmd_mountain(args: &Args) -> Result<()> {
    args.finish()?;
    let params = tlstore::sim::mountain::MountainParams::default();
    let pts = tlstore::sim::mountain_surface(&params);
    println!("storage mountain (simulated at paper scale) — MB/s");
    print!("{:>10}", "data\\skip");
    let skips: Vec<f64> = {
        let mut s: Vec<f64> = pts.iter().map(|p| p.skip_bytes).collect();
        s.dedup();
        s.truncate(16);
        s
    };
    for s in &skips {
        print!("{:>9}", tlstore::util::bytes::fmt_bytes(*s as u64));
    }
    println!();
    let mut row_data = f64::NAN;
    for p in &pts {
        if p.data_bytes != row_data {
            row_data = p.data_bytes;
            print!("\n{:>10}", tlstore::util::bytes::fmt_bytes(p.data_bytes as u64));
        }
        print!("{:>9.0}", p.throughput_mbs);
    }
    println!();
    Ok(())
}

fn usage() -> String {
    "usage: tlstore <info|teragen|terasort|validate|analytics|job|cluster|bench|recover|model|sim|mountain> [flags]\n\
     `tlstore job submit --workload wordcount-topk|log-sessions [--jobs N]` runs named\n\
     multi-stage pipelines through the JobServer (shuffle spilled via .shuffle/);\n\
     `tlstore cluster coordinator|worker|pfs-server` runs the multi-process cluster\n\
     plane (coordinator schedules + re-executes, workers pull tasks over TCP,\n\
     pfs-server exports a striped store; see docs/ARCHITECTURE.md \"cluster plane\");\n\
     `tlstore bench parity [--smoke]` measures TeraSort + both workloads on all four\n\
     backends against the paper's \u{a7}4 models and writes BENCH_fig7.json/BENCH_fig5.json;\n\
     `tlstore bench overlap [--smoke]` A/Bs the hot-path overlap knobs (--overlap-depth\n\
     on terasort/job, --append-coalesce on stores) and writes BENCH_overlap.json;\n\
     storage commands accept --fault-plan \"op=commit,kind=crash,...\" (fault drills)\n\
     and `tlstore recover --root DIR --backend tls|pfs|hdfs` repairs a crashed root;\n\
     see `tlstore <cmd> --help` equivalents in README.md"
        .to_string()
}

fn main() {
    tlstore::util::logger::init();
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n{}", usage());
            std::process::exit(2);
        }
    };
    let result = match args.command.as_deref() {
        Some("info") => cmd_info(&args),
        Some("teragen") => cmd_teragen(&args),
        Some("terasort") => cmd_terasort(&args),
        Some("validate") => cmd_validate(&args),
        Some("analytics") => cmd_analytics(&args),
        Some("job") => cmd_job(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("bench") => cmd_bench(&args),
        Some("recover") => cmd_recover(&args),
        Some("model") => cmd_model(&args),
        Some("sim") => cmd_sim(&args),
        Some("mountain") => cmd_mountain(&args),
        _ => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
