//! # tlstore — Two-Level Storage for Big-Data Analytics on HPC
//!
//! A full reimplementation of *"Big Data Analytics on Traditional HPC
//! Infrastructure Using Two-Level Storage"* (Xuan et al., 2015): an
//! in-memory storage tier (the paper's Tachyon) layered over a striped
//! parallel-file-system tier (the paper's OrangeFS), plus every substrate
//! the paper's evaluation depends on — an HDFS-like replicated baseline, a
//! locality-aware MapReduce engine, the TeraSort benchmark suite, the
//! analytic I/O-throughput models of §4, and a discrete-event cluster
//! simulator standing in for the Palmetto HPC testbed.
//!
//! The compute hot-spots (TeraSort's block sort + range-partition
//! histogram, and the log-analytics column aggregation) are JAX/Pallas
//! kernels AOT-lowered to HLO text at build time (`python/compile/`) and
//! executed from Rust through the PJRT CPU client ([`runtime`]). Python is
//! never on the request path.
//!
//! ## Layer map
//!
//! | Layer | Module | Role |
//! |---|---|---|
//! | L3 | [`storage`] | lock-striped memory tier + parallel striped PFS tier + two-level store |
//! | L3 | [`coordinator`], [`mapreduce`], [`terasort`] | checkpointing/prefetch, engine, workload |
//! | L3 | [`model`], [`sim`] | §4 analytic models + cluster simulator |
//! | L3 | [`runtime`] | PJRT: load + execute AOT artifacts (stubbed without the `pjrt` feature) |
//! | L2/L1 | `python/compile/` | JAX graph + Pallas kernels (build time) |
//!
//! Both storage tiers serve clients concurrently: the memory tier is
//! sharded over `mem_shards` lock stripes with one global capacity
//! accountant, the PFS tier fans every object and range access out across
//! its server directories, and write-through drives both tier legs at
//! once. The knobs thread through [`config::EngineConfig`] / the
//! [`storage::tls::TlsConfig`] builder; `docs/ARCHITECTURE.md` documents
//! the data path and invariants.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tlstore::storage::{tls::{TwoLevelStore, TlsConfig}, WriteMode, ReadMode};
//!
//! let cfg = TlsConfig::builder("/tmp/tls-demo")
//!     .mem_capacity(64 << 20)
//!     .pfs_servers(4)
//!     .mem_shards(8)                 // lock stripes of the memory tier
//!     .concurrent_writethrough(true) // dual-leg §3.2 write path
//!     .build()
//!     .unwrap();
//! let store = TwoLevelStore::open(cfg).unwrap();
//! store.write("dataset/part-0", b"hello", WriteMode::WriteThrough).unwrap();
//! let bytes = store.read("dataset/part-0", ReadMode::TwoLevel).unwrap();
//! assert_eq!(&bytes[..], b"hello");
//! ```

pub mod analytics;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod mapreduce;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod storage;
pub mod terasort;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
