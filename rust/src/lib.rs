//! # tlstore — Two-Level Storage for Big-Data Analytics on HPC
//!
//! A full reimplementation of *"Big Data Analytics on Traditional HPC
//! Infrastructure Using Two-Level Storage"* (Xuan et al., 2015): an
//! in-memory storage tier (the paper's Tachyon) layered over a striped
//! parallel-file-system tier (the paper's OrangeFS), plus every substrate
//! the paper's evaluation depends on — an HDFS-like replicated baseline, a
//! locality-aware MapReduce engine, the TeraSort benchmark suite, the
//! analytic I/O-throughput models of §4, and a discrete-event cluster
//! simulator standing in for the Palmetto HPC testbed.
//!
//! The compute hot-spots (TeraSort's block sort + range-partition
//! histogram, and the log-analytics column aggregation) are JAX/Pallas
//! kernels AOT-lowered to HLO text at build time (`python/compile/`) and
//! executed from Rust through the PJRT CPU client ([`runtime`]). Python is
//! never on the request path.
//!
//! ## Layer map
//!
//! | Layer | Module | Role |
//! |---|---|---|
//! | L3 | [`storage`] | lock-striped memory tier + parallel striped PFS tier + two-level store |
//! | L3 | [`coordinator`], [`mapreduce`], [`terasort`], [`workloads`] | checkpointing/prefetch, job server + pipelines, workloads |
//! | L3 | [`cluster`] | multi-process roles over a length-prefixed TCP wire protocol |
//! | L3 | [`model`], [`sim`] | §4 analytic models + cluster simulator |
//! | L3 | [`runtime`] | PJRT: load + execute AOT artifacts (stubbed without the `pjrt` feature) |
//! | L2/L1 | `python/compile/` | JAX graph + Pallas kernels (build time) |
//!
//! Both storage tiers serve clients concurrently: the memory tier is
//! sharded over `mem_shards` lock stripes with one global capacity
//! accountant, the PFS tier fans every object and range access out across
//! its server directories, and write-through drives both tier legs at
//! once. The storage API is **streaming** (v2): backends hand out
//! [`storage::ObjectReader`] / [`storage::ObjectWriter`] handles whose
//! `read_at` / `append` calls move data chunk-by-chunk through the
//! paper's §3.2 buffers — reads land in caller-owned buffers (zero-copy
//! off the memory tier), writes publish atomically on `commit`, and
//! [`storage::ObjectStore::stat`] replaces the v1 `size`/`exists` pair.
//! The knobs thread through [`config::EngineConfig`] / the
//! [`storage::tls::TlsConfig`] builder; `docs/ARCHITECTURE.md` documents
//! the data path and invariants.
//!
//! The compute plane rides the same streams: [`mapreduce::JobServer`]
//! accepts multi-stage [`mapreduce::PipelineSpec`] jobs
//! (`map → reduce → map → reduce…`), runs several concurrently with
//! admission sized off the memory tier, and **spills every shuffle
//! through `.shuffle/` objects** on the two-level store — intermediate
//! job data takes the paper's write-through path in and the priority
//! read path out, instead of living in coordinator heap. `tlstore job
//! submit --workload wordcount-topk|log-sessions` drives the built-in
//! scenario pipelines ([`workloads`]); TeraSort itself is such a
//! pipeline ([`terasort::terasort_spec`], with a CPU sort fallback when
//! PJRT artifacts are absent).
//!
//! The measurement plane closes the paper's predict-then-measure loop:
//! the pipeline times every split read and partition write
//! ([`metrics::IoStat`] busy-time throughput), [`testing::parity`]
//! compares those measurements against eqs. (1)–(7) evaluated on
//! microbenched host constants ([`model::ClusterParams::single_node`]),
//! and `tlstore bench parity` ([`bench::parity`]) emits the
//! `BENCH_fig7.json` / `BENCH_fig5.json` trajectory files CI archives.
//!
//! ## Quickstart
//!
//! ```no_run
//! use tlstore::storage::{tls::{TwoLevelStore, TlsConfig}, WriteMode, ReadMode};
//! use tlstore::storage::{ObjectReader as _, ObjectWriter as _, ObjectStore};
//!
//! let cfg = TlsConfig::builder("/tmp/tls-demo")
//!     .mem_capacity(64 << 20)
//!     .pfs_servers(4)
//!     .mem_shards(8)                 // lock stripes of the memory tier
//!     .concurrent_writethrough(true) // dual-leg §3.2 write path
//!     .build()
//!     .unwrap();
//! let store = TwoLevelStore::open(cfg).unwrap();
//!
//! // v2 streaming surface: chunked writer, atomic commit
//! let mut w = store.create_with("dataset/part-0", WriteMode::WriteThrough).unwrap();
//! w.append(b"hel").unwrap();
//! w.append(b"lo").unwrap();
//! w.commit().unwrap(); // nothing was visible until here
//!
//! // stat subsumes size/exists; readers copy into caller-owned buffers
//! assert_eq!(store.stat("dataset/part-0").unwrap().size, 5);
//! let r = store.open_with("dataset/part-0", ReadMode::TwoLevel).unwrap();
//! let mut buf = [0u8; 5];
//! assert_eq!(r.read_at(0, &mut buf).unwrap(), 5);
//! assert_eq!(&buf, b"hello");
//!
//! // the v1 whole-object methods still work as adapters
//! let bytes = store.read("dataset/part-0", ReadMode::TwoLevel).unwrap();
//! assert_eq!(&bytes[..], b"hello");
//! ```

/// Disk-to-disk analytics kernels (§5 workloads) over the store.
pub mod analytics;
/// Bench harness: figure reproductions + the parity runner.
pub mod bench;
/// CLI argument parsing and subcommand dispatch.
pub mod cli;
/// Multi-process cluster plane: wire, roles, remote PFS.
pub mod cluster;
/// Configuration: TOML subset, presets, validated knobs.
pub mod config;
/// Checkpointer, prefetcher, and the read/write mode router.
pub mod coordinator;
/// The crate-wide error type and `Result` alias.
pub mod error;
/// Job API v2: map/reduce engine, pipelines, `JobServer`.
pub mod mapreduce;
/// Counters, histograms, and per-phase I/O timelines.
pub mod metrics;
/// The §4 analytic performance models (eqs. 1-7).
pub mod model;
/// PJRT runtime bridge for AOT artifacts (feature-gated).
pub mod runtime;
/// Discrete-event cluster simulator (Figures 5-7).
pub mod sim;
/// Both storage tiers + the two-level store and recovery.
pub mod storage;
/// TeraGen / TeraSort / TeraValidate on the Job API.
pub mod terasort;
/// Shared test harnesses: conformance, crash drills, parity.
pub mod testing;
/// In-tree utilities: CRC32, logger, pool, PRNGs, merge.
pub mod util;
/// Named multi-stage workloads (wordcount-topk, log-sessions).
pub mod workloads;

pub use error::{Error, Result};
