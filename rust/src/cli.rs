//! Minimal CLI argument parser (the offline crate set has no clap).
//!
//! Grammar: `tlstore <command> [--flag value]... [--switch]... [positional]...`
//! Flags may be `--key value` or `--key=value`; `--switch` with no value
//! is boolean. Unknown flags are rejected by [`Args::finish`] so typos
//! fail loudly.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// Leading subcommand, when present.
    pub command: Option<String>,
    /// Non-flag arguments, in order.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                if flag.is_empty() {
                    return Err(Error::InvalidArg("bare `--`".into()));
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// String flag with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        self.consumed.borrow_mut().insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArg(format!("bad value for --{key}: {v}"))),
        }
    }

    /// Byte-size flag (accepts `4M`, `512k`, plain integers).
    pub fn get_bytes(&self, key: &str, default: u64) -> Result<u64> {
        self.consumed.borrow_mut().insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => crate::util::bytes::parse_bytes(v)
                .ok_or_else(|| Error::InvalidArg(format!("bad byte size for --{key}: {v}"))),
        }
    }

    /// Boolean switch.
    pub fn has(&self, key: &str) -> bool {
        self.consumed.borrow_mut().insert(key.to_string());
        self.flags.contains_key(key)
    }

    /// Error on any flag that no handler consumed (typo guard). Call after
    /// all `get*`/`has` lookups.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.flags.keys() {
            if !consumed.contains(k) {
                return Err(Error::InvalidArg(format!("unknown flag --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_flags_positionals() {
        let a = parse(&["terasort", "--reducers", "8", "--backend=tls", "extra"]);
        assert_eq!(a.command.as_deref(), Some("terasort"));
        assert_eq!(a.get_parse("reducers", 1u32).unwrap(), 8);
        assert_eq!(a.get("backend", "hdfs"), "tls");
        assert_eq!(a.positional, vec!["extra"]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["cmd"]);
        assert_eq!(a.get("missing", "dflt"), "dflt");
        assert_eq!(a.get_parse("n", 42u32).unwrap(), 42);
        assert!(!a.has("quick"));
        a.finish().unwrap();
    }

    #[test]
    fn boolean_switches() {
        let a = parse(&["cmd", "--quick", "--out", "x"]);
        assert!(a.has("quick"));
        assert_eq!(a.get("out", ""), "x");
        a.finish().unwrap();
    }

    #[test]
    fn byte_sizes() {
        let a = parse(&["cmd", "--block", "4M"]);
        assert_eq!(a.get_bytes("block", 0).unwrap(), 4 << 20);
        assert_eq!(a.get_bytes("other", 7).unwrap(), 7);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_flag_rejected_by_finish() {
        let a = parse(&["cmd", "--tpyo", "x"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["cmd", "--n", "abc"]);
        assert!(a.get_parse("n", 1u32).is_err());
    }

    #[test]
    fn switch_before_flag_not_swallowed() {
        let a = parse(&["cmd", "--verbose", "--n", "3"]);
        assert!(a.has("verbose"));
        assert_eq!(a.get_parse("n", 0u32).unwrap(), 3);
    }
}
