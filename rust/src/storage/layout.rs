//! Data layout mapping between the memory tier's logical blocks and the
//! PFS tier's stripes (the paper's §3.1 / Figure 3).
//!
//! A block of `block_size` bytes maps onto `block_size / stripe_size`
//! stripes distributed round-robin over the PFS servers. Getting this
//! mapping right is what the paper's "hints" tune: a block should spread
//! across *all* servers so a single block read engages every data node.

use crate::error::{Error, Result};

/// Every reserved dot-key namespace a store may place under its root —
/// the single registry of the tree's hidden object prefixes.
///
/// The four entries map to the subsystems that own them: `.wip/` is the
/// memory tier's staging area for in-flight streaming writes
/// ([`crate::storage::tls`]), `.dirty/` holds evicted dirty blocks
/// awaiting checkpoint ([`crate::storage::tls`]), `.shuffle/` is the job
/// plane's transient spill namespace ([`crate::storage::SHUFFLE_NS`]),
/// and `.quarantine/` parks undecodable objects during recovery
/// ([`crate::storage::pfs::QUARANTINE_NS`]).
///
/// `tlstore-lint`'s `reserved-prefix` rule is anchored here: any
/// `".name/"` key-prefix literal in library code must begin with one of
/// these entries, so a new hidden namespace cannot ship without being
/// registered (and without `docs/FAULT_MODEL.md` saying how `recover()`
/// treats it). The cross-link test below pins the registry to the
/// per-module namespace consts so the two can never drift.
pub const RESERVED_PREFIXES: [&str; 4] = [".wip/", ".dirty/", ".shuffle/", ".quarantine/"];

/// Striping geometry of one object on the PFS tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripeLayout {
    /// Stripe unit in bytes (paper default 64 MB at scale).
    pub stripe_size: u64,
    /// Number of PFS servers the object spreads over.
    pub servers: usize,
}

impl StripeLayout {
    /// A layout; errors if `stripe_size` or `servers` is zero.
    pub fn new(stripe_size: u64, servers: usize) -> Result<Self> {
        if stripe_size == 0 {
            return Err(Error::InvalidArg("stripe_size must be > 0".into()));
        }
        if servers == 0 {
            return Err(Error::InvalidArg("servers must be > 0".into()));
        }
        Ok(Self {
            stripe_size,
            servers,
        })
    }

    /// Total stripes an object of `size` bytes occupies.
    pub fn num_stripes(&self, size: u64) -> u64 {
        size.div_ceil(self.stripe_size)
    }

    /// Server that stores stripe `s` (round-robin — the paper's §5.1
    /// "evenly distributed across 2 data nodes with round-robin fashion").
    pub fn server_of(&self, stripe: u64) -> usize {
        (stripe % self.servers as u64) as usize
    }

    /// Index of stripe `s` within its server's datafile.
    pub fn local_index(&self, stripe: u64) -> u64 {
        stripe / self.servers as u64
    }

    /// Map a byte range `[offset, offset+len)` of an object of `size`
    /// bytes to per-stripe segments `(stripe, server, local_off, seg_len)`,
    /// where `local_off` is the offset inside that server's datafile.
    pub fn map_range(&self, size: u64, offset: u64, len: u64) -> Vec<StripeSegment> {
        let end = (offset + len).min(size);
        if offset >= end {
            return Vec::new();
        }
        let first = offset / self.stripe_size;
        let last = (end - 1) / self.stripe_size;
        (first..=last)
            .map(|s| {
                let stripe_start = s * self.stripe_size;
                let seg_start = offset.max(stripe_start);
                let seg_end = end.min(stripe_start + self.stripe_size);
                StripeSegment {
                    stripe: s,
                    server: self.server_of(s),
                    local_offset: self.local_index(s) * self.stripe_size
                        + (seg_start - stripe_start),
                    object_offset: seg_start,
                    len: seg_end - seg_start,
                }
            })
            .collect()
    }

    /// Bytes of an object of `size` living on `server` (capacity planning
    /// + the load-balance property test).
    pub fn server_bytes(&self, size: u64, server: usize) -> u64 {
        let mut total = 0;
        for s in 0..self.num_stripes(size) {
            if self.server_of(s) == server {
                total += (size - s * self.stripe_size).min(self.stripe_size);
            }
        }
        total
    }

    /// How many distinct servers a single `block_size` block touches —
    /// the §3.1 tuning metric (ideal: min(block/stripe, servers)).
    pub fn servers_per_block(&self, block_size: u64) -> usize {
        let stripes = self.num_stripes(block_size).min(self.servers as u64);
        stripes as usize
    }
}

/// One contiguous piece of a mapped range on one server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripeSegment {
    /// Global stripe index within the object.
    pub stripe: u64,
    /// Server owning the stripe.
    pub server: usize,
    /// Byte offset inside the server's datafile.
    pub local_offset: u64,
    /// Byte offset inside the object.
    pub object_offset: u64,
    /// Segment length in bytes.
    pub len: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_namespace_const() {
        // The registry and the per-module consts must agree exactly: a
        // namespace in one but not the other means either an unregistered
        // hidden prefix (linter-invisible) or a stale registry entry.
        let consts = [
            crate::storage::SHUFFLE_NS,
            crate::storage::pfs::QUARANTINE_NS,
            crate::storage::tls::DIRTY_NS,
            crate::storage::tls::WIP_NS,
        ];
        for c in consts {
            assert!(
                RESERVED_PREFIXES.contains(&c),
                "namespace const {c:?} is not in layout::RESERVED_PREFIXES"
            );
        }
        assert_eq!(
            RESERVED_PREFIXES.len(),
            consts.len(),
            "registry entry without a backing namespace const"
        );
        for p in RESERVED_PREFIXES {
            assert!(
                p.starts_with('.') && p.ends_with('/') && p.len() > 2,
                "registry entry {p:?} is not a `.name/` namespace"
            );
        }
    }

    #[test]
    fn paper_geometry_block_spans_both_servers() {
        // §5.1: 512 MB block, 64 MB stripes, 2 data nodes → 8 chunks, both
        // servers engaged
        let l = StripeLayout::new(64 << 20, 2).unwrap();
        assert_eq!(l.num_stripes(512 << 20), 8);
        assert_eq!(l.servers_per_block(512 << 20), 2);
        let segs = l.map_range(512 << 20, 0, 512 << 20);
        assert_eq!(segs.len(), 8);
        let s0: u64 = segs.iter().filter(|s| s.server == 0).map(|s| s.len).sum();
        let s1: u64 = segs.iter().filter(|s| s.server == 1).map(|s| s.len).sum();
        assert_eq!(s0, s1); // perfect balance
    }

    #[test]
    fn round_robin_placement() {
        let l = StripeLayout::new(10, 3).unwrap();
        assert_eq!(l.server_of(0), 0);
        assert_eq!(l.server_of(1), 1);
        assert_eq!(l.server_of(2), 2);
        assert_eq!(l.server_of(3), 0);
        assert_eq!(l.local_index(0), 0);
        assert_eq!(l.local_index(3), 1);
        assert_eq!(l.local_index(7), 2);
    }

    #[test]
    fn map_range_partial_stripes() {
        let l = StripeLayout::new(10, 2).unwrap();
        // object of 25 bytes: stripes 0(srv0) 1(srv1) 2(srv0, 5 bytes)
        let segs = l.map_range(25, 5, 15);
        assert_eq!(segs.len(), 2);
        assert_eq!(segs[0], StripeSegment { stripe: 0, server: 0, local_offset: 5, object_offset: 5, len: 5 });
        assert_eq!(segs[1], StripeSegment { stripe: 1, server: 1, local_offset: 0, object_offset: 10, len: 10 });
        // clamp at object end
        let segs = l.map_range(25, 20, 100);
        assert_eq!(segs, vec![StripeSegment { stripe: 2, server: 0, local_offset: 10, object_offset: 20, len: 5 }]);
    }

    #[test]
    fn map_range_empty_cases() {
        let l = StripeLayout::new(10, 2).unwrap();
        assert!(l.map_range(25, 25, 10).is_empty());
        assert!(l.map_range(25, 5, 0).is_empty());
        assert!(l.map_range(0, 0, 10).is_empty());
    }

    #[test]
    fn segments_cover_range_exactly() {
        let l = StripeLayout::new(7, 3).unwrap();
        let size = 100u64;
        for (off, len) in [(0, 100), (1, 98), (13, 7), (93, 20), (0, 1)] {
            let segs = l.map_range(size, off, len);
            let covered: u64 = segs.iter().map(|s| s.len).sum();
            let expect = (off + len).min(size).saturating_sub(off);
            assert_eq!(covered, expect, "off={off} len={len}");
            // contiguous in object space
            let mut cur = off;
            for s in &segs {
                assert_eq!(s.object_offset, cur);
                cur += s.len;
            }
        }
    }

    #[test]
    fn server_bytes_sums_to_object() {
        let l = StripeLayout::new(8, 3).unwrap();
        let size = 1000u64;
        let total: u64 = (0..3).map(|s| l.server_bytes(size, s)).sum();
        assert_eq!(total, size);
        // balance within one stripe unit
        for s in 0..3 {
            let b = l.server_bytes(size, s);
            assert!((b as i64 - (size / 3) as i64).unsigned_abs() <= 8 * 2);
        }
    }

    #[test]
    fn rejects_degenerate_layouts() {
        assert!(StripeLayout::new(0, 2).is_err());
        assert!(StripeLayout::new(8, 0).is_err());
    }

    #[test]
    fn servers_per_block_tuning_metric() {
        let l = StripeLayout::new(64, 4).unwrap();
        assert_eq!(l.servers_per_block(64), 1); // one stripe: bad spread
        assert_eq!(l.servers_per_block(256), 4); // engages all servers
        assert_eq!(l.servers_per_block(1024), 4); // capped at server count
    }
}
