//! Storage engines: the paper's two-level storage plus every baseline.
//!
//! - [`memstore`] — the in-memory tier (the paper's **Tachyon**): a
//!   **lock-striped** block store (`mem_shards` stripes keyed by block
//!   hash, per-shard LRU/LFU eviction state, one global CAS-guarded
//!   capacity accountant) so concurrent clients scale instead of
//!   serializing on a single mutex.
//! - [`pfs`] — the parallel-FS tier (the paper's **OrangeFS**): objects
//!   striped round-robin across server directories, with layout hints;
//!   whole-object *and* ranged I/O fan out one task per server through the
//!   shared thread pool.
//! - [`hdfs`] — the baseline: replicated whole blocks on "compute node"
//!   local disks (Hadoop's 1 local + N−1 remote copies).
//! - [`tls`] — the contribution: the two-level store combining the memory
//!   tier with the PFS tier under the paper's three write modes and three
//!   read modes (Figure 4), dual I/O buffers (§3.2) with write-through
//!   driving both tier legs concurrently, and block↔stripe layout mapping
//!   (Figure 3, [`layout`]).
//!
//! All engines implement [`ObjectStore`], so MapReduce jobs and benches are
//! generic over the backend — exactly how the paper swaps HDFS / OrangeFS /
//! two-level under the same TeraSort workload. The concurrency knobs
//! thread through [`crate::config::EngineConfig`] (`mem_shards`,
//! `concurrent_writethrough`, `workers`) and the `TlsConfig` builder; see
//! `docs/ARCHITECTURE.md` for the sharding and lock-order invariants.

pub mod block;
pub mod buffer;
pub mod eviction;
pub mod hdfs;
pub mod layout;
pub mod memstore;
pub mod pfs;
pub mod tls;

use crate::error::Result;

/// The paper's write modes (Figure 4 a–c).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WriteMode {
    /// (a) data lands in the memory tier only (fastest, no persistence
    /// until a checkpoint runs).
    MemOnly,
    /// (b) bypass the memory tier, write straight to the PFS.
    Bypass,
    /// (c) synchronous write-through to memory tier **and** PFS — the mode
    /// the paper models and evaluates.
    #[default]
    WriteThrough,
}

/// The paper's read modes (Figure 4 d–f).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ReadMode {
    /// (d) memory tier only; error if a block was evicted.
    MemOnly,
    /// (e) PFS directly, without caching into the memory tier.
    Bypass,
    /// (f) the primary pattern: memory tier first, fall back to the PFS
    /// and cache what was fetched (priority-based read policy, §3.2).
    #[default]
    TwoLevel,
}

/// Minimal object-store interface every backend implements.
///
/// Objects are immutable once written (the Hadoop write-once-read-many
/// model the paper assumes); `write` to an existing key replaces it.
pub trait ObjectStore: Send + Sync {
    /// Store `data` under `key`.
    fn write(&self, key: &str, data: &[u8]) -> Result<()>;

    /// Fetch the whole object.
    fn read(&self, key: &str) -> Result<Vec<u8>>;

    /// Fetch `len` bytes starting at `offset` (reads clamp at EOF).
    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>>;

    /// Object size in bytes.
    fn size(&self, key: &str) -> Result<u64>;

    /// Whether `key` exists.
    fn exists(&self, key: &str) -> bool;

    /// Remove an object (idempotent: missing keys are not an error).
    fn delete(&self, key: &str) -> Result<()>;

    /// Keys starting with `prefix`, sorted.
    fn list(&self, prefix: &str) -> Vec<String>;

    /// Human name for logs/benches.
    fn kind(&self) -> &'static str;
}

/// Convenience: total bytes under a prefix.
pub fn prefix_bytes(store: &dyn ObjectStore, prefix: &str) -> Result<u64> {
    let mut total = 0;
    for key in store.list(prefix) {
        total += store.size(&key)?;
    }
    Ok(total)
}
