//! Storage engines: the paper's two-level storage plus every baseline.
//!
//! - [`memstore`] — the in-memory tier (the paper's **Tachyon**): a
//!   **lock-striped** block store (`mem_shards` stripes keyed by block
//!   hash, per-shard LRU/LFU eviction state, one global CAS-guarded
//!   capacity accountant) so concurrent clients scale instead of
//!   serializing on a single mutex.
//! - [`pfs`] — the parallel-FS tier (the paper's **OrangeFS**): objects
//!   striped round-robin across server directories, with layout hints;
//!   whole-object *and* ranged I/O fan out one task per server through the
//!   shared thread pool.
//! - [`hdfs`] — the baseline: replicated whole blocks on "compute node"
//!   local disks (Hadoop's 1 local + N−1 remote copies).
//! - [`tls`] — the contribution: the two-level store combining the memory
//!   tier with the PFS tier under the paper's three write modes and three
//!   read modes (Figure 4), dual I/O buffers (§3.2) with write-through
//!   driving both tier legs concurrently, and block↔stripe layout mapping
//!   (Figure 3, [`layout`]).
//! - [`fault`] — deterministic fault injection ([`fault::FaultPlan`] /
//!   [`fault::FaultStore`]): fail, short-read, corrupt, or *crash* any
//!   operation, so the crash suites can prove the durability story
//!   instead of assuming it. Every backend implements [`Recover`], whose
//!   `recover()` repairs or quarantines what a killed process left
//!   behind and reports it as a [`RecoveryReport`] (see
//!   `docs/FAULT_MODEL.md`).
//!
//! All engines implement [`ObjectStore`], so MapReduce jobs and benches are
//! generic over the backend — exactly how the paper swaps HDFS / OrangeFS /
//! two-level under the same TeraSort workload. The v2 surface is
//! **streaming**: [`ObjectStore::open`] returns an [`ObjectReader`] whose
//! `read_at` copies into caller-owned buffers (zero intermediate copies on
//! the memory tier), and [`ObjectStore::create`] returns an
//! [`ObjectWriter`] whose chunked `append`s move data tier-ward as they
//! arrive — the paper's §3.2 dual-buffer path, with atomic
//! `commit`/`abort` so partially written objects are never visible. The
//! whole-object v1 methods remain as default-method adapters. The
//! concurrency knobs thread through [`crate::config::EngineConfig`]
//! (`mem_shards`, `concurrent_writethrough`, `workers`) and the
//! `TlsConfig` builder; see `docs/ARCHITECTURE.md` for the sharding,
//! lock-order, and commit-visibility invariants.

/// Block geometry + per-block CRC framing.
pub mod block;
/// The §3.2 app/PFS buffer pair.
pub mod buffer;
/// LRU/LFU eviction policies.
pub mod eviction;
/// Fault-injection store wrapper for crash drills.
pub mod fault;
/// HDFS-like replicated baseline backend.
pub mod hdfs;
/// Key-namespace layout: the reserved-prefix registry.
pub mod layout;
/// Lock-striped in-memory tier.
pub mod memstore;
/// Striped parallel-FS tier.
pub mod pfs;
/// The two-level store combining both tiers.
pub mod tls;

use std::fmt;

use crate::error::{Error, Result};

/// Namespace prefix for MapReduce shuffle spill objects
/// (`.shuffle/<job>/<stage>/...`). The compute plane
/// ([`crate::mapreduce::JobServer`]) streams every map task's sorted runs
/// through writer handles under this prefix so intermediate job data rides
/// the same two-level data path as job input and output (the paper's
/// thesis applied to the shuffle). Objects here are **transient by
/// contract**: a finished stage deletes its spill set, a finished job
/// deletes its whole `.shuffle/<job>/` subtree, and [`Recover::recover`]
/// reaps anything that survives a crash — shuffle data is recomputable,
/// so recovery *deletes* it (it is never quarantined and never
/// resurrected; see `docs/FAULT_MODEL.md`).
pub const SHUFFLE_NS: &str = ".shuffle/";

/// The paper's write modes (Figure 4 a–c).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WriteMode {
    /// (a) data lands in the memory tier only (fastest, no persistence
    /// until a checkpoint runs).
    MemOnly,
    /// (b) bypass the memory tier, write straight to the PFS.
    Bypass,
    /// (c) synchronous write-through to memory tier **and** PFS — the mode
    /// the paper models and evaluates.
    #[default]
    WriteThrough,
}

/// The paper's read modes (Figure 4 d–f).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ReadMode {
    /// (d) memory tier only; error if a block was evicted.
    MemOnly,
    /// (e) PFS directly, without caching into the memory tier.
    Bypass,
    /// (f) the primary pattern: memory tier first, fall back to the PFS
    /// and cache what was fetched (priority-based read policy, §3.2).
    #[default]
    TwoLevel,
}

/// Metadata of one stored object, returned by [`ObjectStore::stat`].
///
/// `stat` subsumes the v1 `size`/`exists` pair: a successful `stat` means
/// the object exists, and the metadata carries everything a caller needs
/// to plan a streaming read (currently the byte size; the struct is
/// `non_exhaustive` in spirit — new fields ride along as the backends
/// learn to report more).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjectMeta {
    /// The object's key.
    pub key: String,
    /// Object size in bytes.
    pub size: u64,
}

/// Streaming read handle over one immutable object (the v2 read surface).
///
/// A reader is a *stateless* positioned view: `read_at` copies into a
/// **caller-owned** buffer at any offset, holds no cursor, and is safe to
/// share across threads (`&self`, `Send + Sync`). Backends pin whatever
/// snapshot they need at [`ObjectStore::open`] time — the memory tier pins
/// an `Arc<[u8]>` so `read_at` never touches a shard lock and copies
/// nothing except the caller's own `memcpy`.
pub trait ObjectReader: Send + Sync {
    /// Total object size in bytes (fixed at `open`).
    fn len(&self) -> u64;

    /// Whether the object is zero bytes long.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy bytes starting at `offset` into `buf`, returning how many were
    /// copied. Reads clamp at EOF: a short count means the object ended,
    /// and `offset >= len()` yields `Ok(0)`. Implementations hold no lock
    /// across calls; the memory tier takes none at all during `read_at`,
    /// while file-backed backends may briefly serialize concurrent
    /// `read_at`s on a shared descriptor.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize>;
}

/// Streaming write handle building one object chunk by chunk (the v2
/// write surface).
///
/// `append` accepts arbitrarily sized chunks; nothing becomes visible to
/// readers until [`ObjectWriter::commit`] publishes the object. A reader
/// racing an *uncommitted* writer sees the old object (on overwrite) or
/// `NotFound` (fresh key) — never a prefix — and a fresh key's commit is
/// atomic. Racing reads against the commit of an *overwrite* carry the
/// same caveat as the v1 whole-object `write`: the store contract is
/// write-once-read-many, and mid-replacement readers of that one key may
/// observe a verification error until the commit completes.
/// [`ObjectWriter::abort`] (or dropping the writer uncommitted) discards
/// every staged byte and leaves no orphan stripes, replicas, or
/// memory-tier blocks behind.
pub trait ObjectWriter: Send {
    /// Append one chunk to the object being built.
    fn append(&mut self, chunk: &[u8]) -> Result<()>;

    /// Append several chunks in one call, in order. Semantically
    /// identical to calling [`append`](ObjectWriter::append) once per
    /// part; backends override this to turn many small appends into a
    /// single striped fan-out (and the remote client into fewer wire
    /// frames). The default simply loops, so every implementor keeps
    /// the one-append-per-part crash boundaries.
    fn append_vectored(&mut self, parts: &[&[u8]]) -> Result<()> {
        for part in parts {
            self.append(part)?;
        }
        Ok(())
    }

    /// Bytes appended so far (not yet visible to readers).
    fn written(&self) -> u64;

    /// Atomically publish the object under its key, replacing any previous
    /// version. Consumes the writer.
    fn commit(self: Box<Self>) -> Result<()>;

    /// Discard the staged object without publishing. Consumes the writer.
    fn abort(self: Box<Self>) -> Result<()>;
}

/// Minimal object-store interface every backend implements.
///
/// Objects are immutable once written (the Hadoop write-once-read-many
/// model the paper assumes); committing a writer for an existing key
/// replaces the object.
///
/// The v2 surface is handle-based: [`ObjectStore::open`] /
/// [`ObjectStore::create`] / [`ObjectStore::stat`] are what backends
/// implement natively, mapping the paper's §3.2 chunked buffer path onto
/// per-chunk `read_at`/`append` calls. The v1 whole-object methods
/// (`read`, `read_range`, `write`, `size`, `exists`) are default-method
/// adapters over the handles so existing callers keep compiling; backends
/// may still override them where a whole-object fast path exists.
pub trait ObjectStore: Send + Sync {
    /// Open a streaming reader over `key`.
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>>;

    /// Start a streaming writer that will publish `key` on commit.
    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>>;

    /// Object metadata; `Err(NotFound)` if the key does not exist.
    fn stat(&self, key: &str) -> Result<ObjectMeta>;

    /// Remove an object (idempotent: missing keys are not an error).
    fn delete(&self, key: &str) -> Result<()>;

    /// Keys starting with `prefix`, sorted.
    fn list(&self, prefix: &str) -> Vec<String>;

    /// Human name for logs/benches.
    fn kind(&self) -> &'static str;

    // ---- v1 compatibility adapters (default methods over the handles) ----

    /// Store `data` under `key` (adapter: `create` → one `append` →
    /// `commit`).
    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        let mut w = self.create(key)?;
        w.append(data)?;
        w.commit()
    }

    /// Fetch the whole object (adapter over [`ObjectStore::open`]).
    fn read(&self, key: &str) -> Result<Vec<u8>> {
        let r = self.open(key)?;
        let mut out = vec![0u8; r.len() as usize];
        read_full_at(r.as_ref(), 0, &mut out)?;
        Ok(out)
    }

    /// Fetch `len` bytes starting at `offset` (reads clamp at EOF; adapter
    /// over [`ObjectStore::open`]).
    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        let r = self.open(key)?;
        let take = clamped_len(offset, len, r.len());
        let mut out = vec![0u8; take];
        if take > 0 {
            read_full_at(r.as_ref(), offset, &mut out)?;
        }
        Ok(out)
    }

    /// Object size in bytes (adapter over [`ObjectStore::stat`]).
    fn size(&self, key: &str) -> Result<u64> {
        Ok(self.stat(key)?.size)
    }

    /// Whether `key` exists (adapter over [`ObjectStore::stat`]).
    fn exists(&self, key: &str) -> bool {
        self.stat(key).is_ok()
    }
}

/// What one [`Recover::recover`] pass found and did. All counters are 0
/// and all lists empty on a clean store ([`RecoveryReport::is_clean`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Writer temp files removed (`*.df.tmp-*`, `*.blk.tmp-*`,
    /// `*.meta.tmp`) plus abandoned in-memory `.wip/` staging blocks.
    pub temps_removed: u64,
    /// Published-namespace files with no owning metadata (e.g. datafiles a
    /// crashed commit renamed before its meta landed) that were removed.
    pub orphans_removed: u64,
    /// Stale `.dirty/` spill objects of already-checkpointed objects that
    /// were dropped.
    pub spills_dropped: u64,
    /// Keys whose on-disk state was inconsistent (truncated datafiles,
    /// checksum mismatch, undecodable metadata, spills of an uncommitted
    /// memory-only object) — moved aside under the quarantine namespace so
    /// they read as `NotFound` instead of serving corrupt or resurrected
    /// bytes. The files are preserved for forensics.
    pub quarantined: Vec<String>,
    /// Keys restored to full health (e.g. re-replicated or healed to a
    /// consistent replica set).
    pub repaired: Vec<String>,
    /// Transient shuffle spill objects (under [`SHUFFLE_NS`]) deleted by
    /// recovery. Shuffle data is recomputable intermediate state: a crash
    /// mid-job may leave spills behind, and recovery drops them outright
    /// (deleted, not quarantined — resurrecting a partial spill set would
    /// feed a reducer a prefix).
    pub shuffle_reaped: u64,
}

impl RecoveryReport {
    /// Whether recovery found nothing to do.
    pub fn is_clean(&self) -> bool {
        self.temps_removed == 0
            && self.orphans_removed == 0
            && self.spills_dropped == 0
            && self.quarantined.is_empty()
            && self.repaired.is_empty()
            && self.shuffle_reaped == 0
    }

    /// Fold another report (e.g. an inner tier's) into this one.
    pub fn absorb(&mut self, other: RecoveryReport) {
        self.temps_removed += other.temps_removed;
        self.orphans_removed += other.orphans_removed;
        self.spills_dropped += other.spills_dropped;
        self.quarantined.extend(other.quarantined);
        self.repaired.extend(other.repaired);
        self.shuffle_reaped += other.shuffle_reaped;
    }
}

impl fmt::Display for RecoveryReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return write!(f, "clean (nothing to recover)");
        }
        write!(
            f,
            "temps_removed={} orphans_removed={} spills_dropped={} shuffle_reaped={} quarantined={:?} repaired={:?}",
            self.temps_removed,
            self.orphans_removed,
            self.spills_dropped,
            self.shuffle_reaped,
            self.quarantined,
            self.repaired
        )
    }
}

/// Crash recovery: scan the backend's surviving state for debris a killed
/// process left behind (writer temp files, half-committed objects, orphan
/// spills), then repair or quarantine it.
///
/// The contract `recover()` restores is the crash-consistency invariant
/// the conformance/crash suites assert: after a crash + reopen +
/// `recover()`, **every key reads as fully the old version, fully the new
/// version, or `NotFound` — never a prefix, and an uncommitted or
/// volatile write is never resurrected** — and no writer temp files
/// remain on disk. Run it once after reopening a store over a directory
/// tree whose previous owner may have died (see `docs/FAULT_MODEL.md`),
/// and **before** starting writers: recovery reaps writer staging, so an
/// in-flight writer's temps look exactly like a dead one's.
pub trait Recover {
    /// Scan and repair; returns what was found. Errors only when the
    /// repair itself cannot proceed (e.g. the filesystem refuses the
    /// cleanup) — an unrecoverable *object* is quarantined, not an error.
    fn recover(&self) -> Result<RecoveryReport>;
}

/// Whether `name` is a *writer temp* file name: `*.df.tmp-<digits>` (PFS
/// datafile staging), `*.blk.tmp-<digits>` (HDFS replica staging), or
/// `*.meta.tmp` (torn PFS metadata). Anchored at the end of the name —
/// keys that merely *contain* these substrings (e.g. an object named
/// `backup/app.df.tmp-old`, whose datafile is `…app.df.tmp-old.df`) are
/// **not** temps and must survive recovery.
pub fn is_writer_temp(name: &str) -> bool {
    if name.ends_with(".meta.tmp") {
        return true;
    }
    for infix in [".df.tmp-", ".blk.tmp-"] {
        if let Some(i) = name.rfind(infix) {
            let token = &name[i + infix.len()..];
            if !token.is_empty() && token.bytes().all(|b| b.is_ascii_digit()) {
                return true;
            }
        }
    }
    false
}

// ---- forwarding impls -----------------------------------------------------
// `&T`, `Box<T>`, and `Arc<T>` store views behave exactly like `T`: every
// method (including the v1 adapters, which backends may override with fast
// paths) forwards to the underlying store. These make wrappers like
// `FaultStore` usable over borrowed and shared stores.

macro_rules! forward_object_store {
    () => {
        fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
            (**self).open(key)
        }
        fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
            (**self).create(key)
        }
        fn stat(&self, key: &str) -> Result<ObjectMeta> {
            (**self).stat(key)
        }
        fn delete(&self, key: &str) -> Result<()> {
            (**self).delete(key)
        }
        fn list(&self, prefix: &str) -> Vec<String> {
            (**self).list(prefix)
        }
        fn kind(&self) -> &'static str {
            (**self).kind()
        }
        fn write(&self, key: &str, data: &[u8]) -> Result<()> {
            (**self).write(key, data)
        }
        fn read(&self, key: &str) -> Result<Vec<u8>> {
            (**self).read(key)
        }
        fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
            (**self).read_range(key, offset, len)
        }
        fn size(&self, key: &str) -> Result<u64> {
            (**self).size(key)
        }
        fn exists(&self, key: &str) -> bool {
            (**self).exists(key)
        }
    };
}

impl<T: ObjectStore + ?Sized> ObjectStore for &T {
    forward_object_store!();
}

impl<T: ObjectStore + ?Sized> ObjectStore for Box<T> {
    forward_object_store!();
}

impl<T: ObjectStore + ?Sized> ObjectStore for std::sync::Arc<T> {
    forward_object_store!();
}

/// Fill `buf` completely from `offset`, looping [`ObjectReader::read_at`]
/// until done. Errors if the object ends before `buf` is filled — use this
/// when the caller already clamped the request to `len()`.
pub fn read_full_at(reader: &dyn ObjectReader, offset: u64, buf: &mut [u8]) -> Result<()> {
    let mut done = 0usize;
    while done < buf.len() {
        let n = reader.read_at(offset + done as u64, &mut buf[done..])?;
        if n == 0 {
            return Err(Error::NotFound(format!(
                "object truncated at offset {} ({} bytes still expected)",
                offset + done as u64,
                buf.len() - done
            )));
        }
        done += n;
    }
    Ok(())
}

/// Clamp an `(offset, len)` request against an object of `size` bytes,
/// returning how many bytes are actually readable (0 when `offset` is at
/// or past EOF). The shared EOF arithmetic behind every ranged adapter.
pub fn clamped_len(offset: u64, len: usize, size: u64) -> usize {
    let end = offset.saturating_add(len as u64).min(size);
    end.saturating_sub(offset.min(end)) as usize
}

/// Copy `src[offset..]` into `buf`, clamped at EOF; returns bytes copied.
/// The shared EOF-clamping kernel the in-memory readers use.
pub(crate) fn copy_clamped(src: &[u8], offset: u64, buf: &mut [u8]) -> usize {
    if offset >= src.len() as u64 {
        return 0;
    }
    let start = offset as usize;
    let n = (src.len() - start).min(buf.len());
    buf[..n].copy_from_slice(&src[start..start + n]);
    n
}

/// Delete every object under `prefix` through the store's own API,
/// returning how many were removed. A key that vanishes mid-reap (e.g. a
/// concurrent delete) is not an error; any other delete failure aborts
/// the sweep. The one shared cleanup kernel behind shuffle reaping — the
/// executor's per-job/per-round sweeps, [`JobServer::shutdown`]'s
/// per-id sweep, and the recovery passes all route through it.
///
/// [`JobServer::shutdown`]: crate::mapreduce::JobServer::shutdown
pub fn reap_prefix(store: &dyn ObjectStore, prefix: &str) -> Result<u64> {
    let mut reaped = 0;
    for key in store.list(prefix) {
        match store.delete(&key) {
            Ok(()) | Err(Error::NotFound(_)) => reaped += 1,
            Err(e) => return Err(e),
        }
    }
    Ok(reaped)
}

/// Delete every object under [`SHUFFLE_NS`]: shuffle spills are
/// transient job state, and the backends' [`Recover::recover`] passes
/// reap them with this helper so a crashed job cannot leave
/// intermediate data behind. Do **not** call this while a
/// [`crate::mapreduce::JobServer`] may be running jobs against the
/// store — live jobs own their `.shuffle/<id>/` subtrees.
pub fn reap_shuffle(store: &dyn ObjectStore) -> Result<u64> {
    reap_prefix(store, SHUFFLE_NS)
}

/// Convenience: total bytes under a prefix, via [`ObjectStore::stat`].
///
/// A key deleted between `list` and `stat` counts as 0 bytes instead of
/// failing the whole sum (the v1 version surfaced the race as an error).
pub fn prefix_bytes(store: &dyn ObjectStore, prefix: &str) -> Result<u64> {
    let mut total = 0;
    for key in store.list(prefix) {
        match store.stat(&key) {
            Ok(meta) => total += meta.size,
            Err(Error::NotFound(_)) => {} // deleted between list and stat
            Err(e) => return Err(e),
        }
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::memstore::MemStore;

    /// Delegates only the v2 required methods, so every v1 call in these
    /// tests exercises the trait's default-method adapters.
    struct HandleOnly(MemStore);

    impl ObjectStore for HandleOnly {
        fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
            self.0.open(key)
        }
        fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
            self.0.create(key)
        }
        fn stat(&self, key: &str) -> Result<ObjectMeta> {
            self.0.stat(key)
        }
        fn delete(&self, key: &str) -> Result<()> {
            ObjectStore::delete(&self.0, key)
        }
        fn list(&self, prefix: &str) -> Vec<String> {
            ObjectStore::list(&self.0, prefix)
        }
        fn kind(&self) -> &'static str {
            "handle-only"
        }
    }

    fn handle_store() -> HandleOnly {
        HandleOnly(MemStore::new(u64::MAX, "lru").unwrap())
    }

    #[test]
    fn default_adapters_cover_the_v1_surface() {
        let s = handle_store();
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        s.write("p/a", &data).unwrap();
        assert_eq!(s.read("p/a").unwrap(), data);
        assert_eq!(s.read_range("p/a", 100, 50).unwrap(), &data[100..150]);
        assert_eq!(s.read_range("p/a", 990, 100).unwrap(), &data[990..]);
        assert_eq!(s.read_range("p/a", 1000, 5).unwrap(), Vec::<u8>::new());
        assert_eq!(s.read_range("p/a", 5000, 5).unwrap(), Vec::<u8>::new());
        assert_eq!(s.size("p/a").unwrap(), 1000);
        assert!(s.exists("p/a"));
        assert!(!s.exists("p/b"));
        s.delete("p/a").unwrap();
        assert!(!s.exists("p/a"));
    }

    #[test]
    fn writer_temp_matcher_is_anchored() {
        // real writer temps
        assert!(is_writer_temp("k.df.tmp-0"));
        assert!(is_writer_temp("in%2Fpart-3.df.tmp-1234"));
        assert!(is_writer_temp("obj.blk.tmp-7"));
        assert!(is_writer_temp("k.meta.tmp"));
        // a key *containing* the pattern is not a temp once published
        assert!(!is_writer_temp("backup%2Fapp.df.tmp-old.df"));
        assert!(!is_writer_temp("evil.df.tmp-5.df"));
        assert!(!is_writer_temp("evil.blk.tmp-5.blk"));
        // but that key's own writer temp still is one
        assert!(is_writer_temp("evil.df.tmp-5.df.tmp-99"));
        assert!(!is_writer_temp("k.df"));
        assert!(!is_writer_temp("k.meta"));
        assert!(!is_writer_temp("k.df.tmp-"));
    }

    #[test]
    fn clamped_len_edges() {
        assert_eq!(clamped_len(0, 10, 100), 10);
        assert_eq!(clamped_len(95, 10, 100), 5);
        assert_eq!(clamped_len(100, 10, 100), 0);
        assert_eq!(clamped_len(500, 10, 100), 0);
        assert_eq!(clamped_len(0, 0, 100), 0);
        assert_eq!(clamped_len(u64::MAX, usize::MAX, u64::MAX), 0);
        assert_eq!(clamped_len(0, 10, 0), 0);
    }

    #[test]
    fn copy_clamped_edges() {
        let src = [1u8, 2, 3, 4, 5];
        let mut buf = [0u8; 3];
        assert_eq!(copy_clamped(&src, 0, &mut buf), 3);
        assert_eq!(buf, [1, 2, 3]);
        assert_eq!(copy_clamped(&src, 3, &mut buf), 2);
        assert_eq!(&buf[..2], &[4, 5]);
        assert_eq!(copy_clamped(&src, 5, &mut buf), 0);
        assert_eq!(copy_clamped(&src, 99, &mut buf), 0);
        assert_eq!(copy_clamped(&src, 0, &mut []), 0);
    }

    /// `list` reports a key that no longer exists — the list/stat race
    /// `prefix_bytes` must absorb as 0 bytes, not an error.
    struct GhostList(MemStore);

    impl ObjectStore for GhostList {
        fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
            self.0.open(key)
        }
        fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
            self.0.create(key)
        }
        fn stat(&self, key: &str) -> Result<ObjectMeta> {
            self.0.stat(key)
        }
        fn delete(&self, key: &str) -> Result<()> {
            ObjectStore::delete(&self.0, key)
        }
        fn list(&self, prefix: &str) -> Vec<String> {
            let mut keys = ObjectStore::list(&self.0, prefix);
            keys.push(format!("{prefix}ghost-deleted-since-list"));
            keys
        }
        fn kind(&self) -> &'static str {
            "ghost"
        }
    }

    #[test]
    fn reap_shuffle_removes_only_the_namespace() {
        let s = handle_store();
        s.write(".shuffle/job-1/s0/m00000-p00000-r0", b"run").unwrap();
        s.write(".shuffle/job-2/inter-1/part-r-00000", b"inter").unwrap();
        s.write("user/data", b"keep").unwrap();
        assert_eq!(reap_shuffle(&s).unwrap(), 2);
        assert!(s.list(SHUFFLE_NS).is_empty());
        assert!(s.exists("user/data"));
        assert_eq!(reap_shuffle(&s).unwrap(), 0, "idempotent");
    }

    #[test]
    fn recovery_report_counts_shuffle_reaping() {
        let mut r = RecoveryReport::default();
        assert!(r.is_clean());
        r.shuffle_reaped = 3;
        assert!(!r.is_clean());
        assert!(r.to_string().contains("shuffle_reaped=3"));
        let mut total = RecoveryReport::default();
        total.absorb(r);
        assert_eq!(total.shuffle_reaped, 3);
    }

    #[test]
    fn prefix_bytes_treats_vanished_keys_as_zero() {
        let s = GhostList(MemStore::new(u64::MAX, "lru").unwrap());
        ObjectStore::write(&s.0, "p/a", &[0u8; 100]).unwrap();
        ObjectStore::write(&s.0, "p/b", &[0u8; 50]).unwrap();
        assert_eq!(s.list("p/").len(), 3, "ghost key is listed");
        assert_eq!(prefix_bytes(&s, "p/").unwrap(), 150);
    }
}
