//! HDFS-like baseline: replicated whole objects on compute-node local
//! disks.
//!
//! Hadoop's write path ("one copy to local disk, two mirrored copies
//! streamed to other nodes", §4.1) is reproduced structurally: `nodes`
//! directories stand in for the compute nodes' single SATA disks, an
//! object's *primary* replica lands on the node that wrote it, and
//! `replication - 1` mirror copies go to other nodes. Reads prefer the
//! local replica (Hadoop's locality scheduling); a read from a node
//! without a replica counts as a remote read — the quantity the §4.1
//! model charges network bandwidth for.

use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::storage::block::checksum;
use crate::storage::pfs::remove_existing;
use crate::storage::{
    clamped_len, is_writer_temp, reap_shuffle, ObjectMeta, ObjectReader, ObjectStore,
    ObjectWriter, Recover, RecoveryReport, SHUFFLE_NS,
};
use crate::util::pool::ThreadPool;
use crate::util::rng::SplitMix64;

/// Uniquifies in-flight writer temp replicas.
static HDFS_WRITER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Counters (note `bytes_written_physical` ≈ 3× logical — the paper's
/// write-amplification argument).
#[derive(Debug, Clone, Copy, Default)]
pub struct HdfsStats {
    /// Bytes the caller asked to write (before replication).
    pub bytes_written_logical: u64,
    /// Bytes actually written across all replicas.
    pub bytes_written_physical: u64,
    /// Bytes served to readers.
    pub bytes_read: u64,
    /// Reads satisfied by the reader's own node.
    pub local_reads: u64,
    /// Reads that crossed to another node's replica.
    pub remote_reads: u64,
}

/// Replicated local-disk object store.
pub struct HdfsLike {
    node_dirs: Vec<PathBuf>,
    replication: usize,
    pool: Arc<ThreadPool>,
    /// Node id this client "runs on" (for locality accounting).
    pub local_node: usize,
    /// Coalesce streaming-writer appends until at least this many bytes
    /// are buffered, then mirror them to the replicas in one fan-out
    /// (`0` = append-through, the historical behavior). Snapshotted per
    /// writer at `create`.
    pub append_coalesce: usize,
    logical: AtomicU64,
    physical: AtomicU64,
    read_bytes: AtomicU64,
    local_reads: AtomicU64,
    remote_reads: AtomicU64,
}

impl HdfsLike {
    /// Open with `nodes` node directories and `replication` copies.
    pub fn open(root: &Path, nodes: usize, replication: usize) -> Result<Self> {
        if nodes == 0 {
            return Err(Error::Config("hdfs needs at least one node".into()));
        }
        let replication = replication.clamp(1, nodes);
        let mut node_dirs = Vec::with_capacity(nodes);
        for n in 0..nodes {
            let dir = root.join(format!("node{n}"));
            fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
            node_dirs.push(dir);
        }
        Ok(Self {
            node_dirs,
            replication,
            pool: Arc::new(ThreadPool::new(replication.max(2))),
            local_node: 0,
            append_coalesce: 0,
            logical: AtomicU64::new(0),
            physical: AtomicU64::new(0),
            read_bytes: AtomicU64::new(0),
            local_reads: AtomicU64::new(0),
            remote_reads: AtomicU64::new(0),
        })
    }

    /// Simulated datanode count.
    pub fn nodes(&self) -> usize {
        self.node_dirs.len()
    }

    /// Replication factor applied to writes.
    pub fn replication(&self) -> usize {
        self.replication
    }

    /// Snapshot of the backend's counters.
    pub fn stats(&self) -> HdfsStats {
        HdfsStats {
            bytes_written_logical: self.logical.load(Ordering::Relaxed),
            bytes_written_physical: self.physical.load(Ordering::Relaxed),
            bytes_read: self.read_bytes.load(Ordering::Relaxed),
            local_reads: self.local_reads.load(Ordering::Relaxed),
            remote_reads: self.remote_reads.load(Ordering::Relaxed),
        }
    }

    fn enc(key: &str) -> String {
        key.replace('%', "%25").replace('/', "%2F")
    }

    fn replica_path(&self, key: &str, node: usize) -> PathBuf {
        self.node_dirs[node].join(format!("{}.blk", Self::enc(key)))
    }

    /// Replica placement: primary on `local_node`, mirrors deterministic
    /// pseudo-random (keyed by object name, like HDFS's random target
    /// choice but reproducible for tests).
    pub fn replica_nodes(&self, key: &str) -> Vec<usize> {
        let n = self.node_dirs.len();
        let mut nodes = vec![self.local_node];
        let mut rng = SplitMix64::new(key.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64)));
        while nodes.len() < self.replication {
            let cand = (rng.next_u64() % n as u64) as usize;
            if !nodes.contains(&cand) {
                nodes.push(cand);
            }
        }
        nodes
    }

    fn find_replica(&self, key: &str) -> Option<usize> {
        // prefer local
        if self.replica_path(key, self.local_node).exists() {
            return Some(self.local_node);
        }
        (0..self.node_dirs.len()).find(|&n| self.replica_path(key, n).exists())
    }

    // -- crash recovery ----------------------------------------------------

    /// Crash recovery for the replicated baseline; see [`Recover`] for the
    /// contract.
    ///
    /// 1. **Writer temp replicas** — `*.blk.tmp-<token>` staging of
    ///    abandoned [`HdfsWriter`]s is removed (commit renames temps into
    ///    place; a surviving temp belongs to a commit that never ran).
    /// 2. **Replica healing** — every replica of an object is a *complete*
    ///    copy, so a crashed overwrite commit can leave a mixed set (some
    ///    nodes new, some old) or an under-replicated one (a commit that
    ///    died between renames, or a lost disk). Recovery elects the
    ///    replica on the lowest-numbered surviving node, rewrites any
    ///    replica whose checksum diverges from it, and re-mirrors it to
    ///    the key's placement nodes that lost their copy — restoring
    ///    "every reader sees one consistent version at full replication".
    ///
    /// Healing is itself crash-safe: repaired replicas are staged as
    /// `*.blk.tmp-0` temps and renamed into place, so a crash mid-heal
    /// can never tear a replica that the *next* recovery would elect as
    /// its source — the surviving temp is simply reaped by that run's
    /// pass 1.
    pub fn recover_hdfs(&self) -> Result<RecoveryReport> {
        let mut report = RecoveryReport::default();

        // pass 1: writer temps
        for dir in &self.node_dirs {
            let entries = fs::read_dir(dir).map_err(|e| Error::io(dir, e))?;
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if is_writer_temp(&name) && remove_existing(&entry.path())? {
                    report.temps_removed += 1;
                }
            }
        }

        // atomic replica install: stage + rename, never a torn target
        let install = |node: usize, key: &str, bytes: &[u8]| -> Result<()> {
            let dst = self.replica_path(key, node);
            let tmp = self.node_dirs[node].join(format!("{}.blk.tmp-0", Self::enc(key)));
            fs::write(&tmp, bytes).map_err(|e| Error::io(&tmp, e))?;
            fs::rename(&tmp, &dst).map_err(|e| Error::io(&dst, e))
        };

        // pass 2: replica healing
        for key in self.list("") {
            if key.starts_with(SHUFFLE_NS) {
                continue; // transient — pass 3 deletes it, don't heal it
            }
            let present: Vec<usize> = (0..self.node_dirs.len())
                .filter(|&n| self.replica_path(&key, n).exists())
                .collect();
            let Some(&src_node) = present.first() else {
                continue; // raced a delete
            };
            let src_path = self.replica_path(&key, src_node);
            let src = fs::read(&src_path).map_err(|e| Error::io(&src_path, e))?;
            let src_crc = checksum(&src);
            let mut healed = false;
            // heal divergent survivors to the elected copy
            for &n in present.iter().skip(1) {
                let path = self.replica_path(&key, n);
                let bytes = fs::read(&path).map_err(|e| Error::io(&path, e))?;
                if bytes.len() != src.len() || checksum(&bytes) != src_crc {
                    install(n, &key, &src)?;
                    healed = true;
                }
            }
            // restore full replication on the key's placement nodes
            for n in self.replica_nodes(&key) {
                if !self.replica_path(&key, n).exists() {
                    install(n, &key, &src)?;
                    healed = true;
                }
            }
            if healed {
                report.repaired.push(key);
            }
        }

        // pass 3: reap shuffle spill residue — transient job data that a
        // crashed run left behind (healing above may first have restored
        // a spill's replica set; deleting it afterwards is still correct,
        // the data is recomputable by contract)
        report.shuffle_reaped += reap_shuffle(self)?;
        Ok(report)
    }
}

impl Recover for HdfsLike {
    fn recover(&self) -> Result<RecoveryReport> {
        self.recover_hdfs()
    }
}

/// Streaming reader over one replica: the replica is chosen at `open`
/// (local preferred — one locality-accounting event per handle, not per
/// `read_at`) and its file handle is shared behind a mutex for positioned
/// reads.
pub struct HdfsReader<'a> {
    hdfs: &'a HdfsLike,
    path: PathBuf,
    file: Mutex<fs::File>,
    size: u64,
}

impl ObjectReader for HdfsReader<'_> {
    fn len(&self) -> u64 {
        self.size
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        let take = clamped_len(offset, buf.len(), self.size);
        if take == 0 {
            return Ok(0);
        }
        let mut f = self.file.lock().unwrap();
        f.seek(SeekFrom::Start(offset))
            .map_err(|e| Error::io(&self.path, e))?;
        f.read_exact(&mut buf[..take])
            .map_err(|e| Error::io(&self.path, e))?;
        drop(f);
        self.hdfs
            .read_bytes
            .fetch_add(take as u64, Ordering::Relaxed);
        Ok(take)
    }
}

/// Streaming replicated writer: every `append` is mirrored to all
/// `replication` replicas as it arrives (Hadoop's synchronous per-packet
/// pipeline, structurally), into `*.blk.tmp-<token>` files invisible to
/// readers; `commit` renames each replica into place. `abort` (or
/// dropping uncommitted) deletes the temp replicas.
pub struct HdfsWriter<'a> {
    hdfs: &'a HdfsLike,
    key: String,
    nodes: Vec<usize>,
    files: Vec<fs::File>,
    token: u64,
    written: u64,
    /// Coalescing threshold snapshotted from [`HdfsLike::append_coalesce`].
    coalesce: usize,
    /// Bytes buffered awaiting the next coalesced flush (always empty
    /// when `coalesce == 0`).
    carry: Vec<u8>,
    finished: bool,
}

impl HdfsWriter<'_> {
    fn tmp_path(&self, node: usize) -> PathBuf {
        self.hdfs.node_dirs[node].join(format!(
            "{}.blk.tmp-{}",
            HdfsLike::enc(&self.key),
            self.token
        ))
    }

    /// Mirror one chunk to every replica temp file (the raw,
    /// pre-coalescing append path).
    fn append_raw(&mut self, chunk: &[u8]) -> Result<()> {
        // below this, per-replica thread fan-out costs more than it overlaps
        const PARALLEL_APPEND_MIN: usize = 128 << 10;

        if self.files.len() > 1 && chunk.len() >= PARALLEL_APPEND_MIN {
            // mirror the whole-object write: one leg per replica at once
            let paths: Vec<PathBuf> = self.nodes.iter().map(|&n| self.tmp_path(n)).collect();
            let results: Vec<Result<()>> = std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .files
                    .iter_mut()
                    .zip(&paths)
                    .map(|(f, path)| {
                        scope.spawn(move || {
                            f.write_all(chunk).map_err(|e| Error::io(path, e))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        // a panicked leg fails the append instead of tearing
                        // down the writer's thread
                        h.join().unwrap_or_else(|_| {
                            Err(Error::Job("replica write leg panicked".into()))
                        })
                    })
                    .collect()
            });
            for r in results {
                r?;
            }
        } else {
            for (i, f) in self.files.iter_mut().enumerate() {
                f.write_all(chunk)
                    .map_err(|e| Error::io(self.hdfs.node_dirs[self.nodes[i]].as_path(), e))?;
            }
        }
        self.written += chunk.len() as u64;
        Ok(())
    }

    /// Mirror out the coalescing carry, keeping its allocation for the
    /// next batch.
    fn flush_carry(&mut self) -> Result<()> {
        if self.carry.is_empty() {
            return Ok(());
        }
        let mut full = std::mem::take(&mut self.carry);
        self.append_raw(&full)?;
        full.clear();
        self.carry = full;
        Ok(())
    }

    fn cleanup(&mut self) {
        self.finished = true;
        self.carry.clear();
        self.files.clear(); // close handles before unlinking
        for &n in &self.nodes {
            let _ = fs::remove_file(self.tmp_path(n));
        }
    }
}

impl Drop for HdfsWriter<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.cleanup();
        }
    }
}

impl ObjectWriter for HdfsWriter<'_> {
    fn append(&mut self, chunk: &[u8]) -> Result<()> {
        if self.coalesce == 0 {
            return self.append_raw(chunk);
        }
        // already-large chunks skip the copy through the carry
        if self.carry.is_empty() && chunk.len() >= self.coalesce {
            return self.append_raw(chunk);
        }
        self.carry.extend_from_slice(chunk);
        if self.carry.len() >= self.coalesce {
            self.flush_carry()?;
        }
        Ok(())
    }

    fn append_vectored(&mut self, parts: &[&[u8]]) -> Result<()> {
        match parts {
            [] => Ok(()),
            [one] => ObjectWriter::append(self, one),
            _ => {
                let total: usize = parts.iter().map(|p| p.len()).sum();
                if self.coalesce != 0 {
                    self.carry.reserve(total);
                    for p in parts {
                        self.carry.extend_from_slice(p);
                    }
                    if self.carry.len() >= self.coalesce {
                        self.flush_carry()?;
                    }
                    Ok(())
                } else {
                    // append-through mode: join once so the replica
                    // fan-out sees a single large chunk instead of N
                    // sub-threshold ones
                    let mut joined = Vec::with_capacity(total);
                    for p in parts {
                        joined.extend_from_slice(p);
                    }
                    self.append_raw(&joined)
                }
            }
        }
    }

    fn written(&self) -> u64 {
        self.written + self.carry.len() as u64
    }

    fn commit(mut self: Box<Self>) -> Result<()> {
        // a coalescing writer may still hold a sub-threshold batch
        if let Err(e) = self.flush_carry() {
            self.cleanup();
            return Err(e);
        }
        self.finished = true;
        self.files.clear(); // close handles before renaming
        let fresh = !self.hdfs.exists(&self.key);
        let mut renamed = Vec::with_capacity(self.nodes.len());
        let mut err = None;
        for &n in &self.nodes {
            let tmp = self.tmp_path(n);
            let dst = self.hdfs.replica_path(&self.key, n);
            match fs::rename(&tmp, &dst) {
                Ok(()) => renamed.push(n),
                Err(e) => {
                    err = Some(Error::io(&dst, e));
                    break;
                }
            }
        }
        if let Some(e) = err {
            // No temp replicas may leak. For a *fresh* key, un-publish the
            // already-renamed replicas so a commit that returned Err is
            // not partially visible. For an overwrite, the renamed
            // replicas already displaced old copies — removing them would
            // only shrink the key's surviving replica count further, so
            // they stay (every replica is a whole object; readers see a
            // complete old or new copy, the WORM overwrite caveat).
            if fresh {
                for &n in &renamed {
                    let _ = fs::remove_file(self.hdfs.replica_path(&self.key, n));
                }
            }
            for &n in &self.nodes {
                let _ = fs::remove_file(self.tmp_path(n));
            }
            return Err(e);
        }
        self.hdfs
            .logical
            .fetch_add(self.written, Ordering::Relaxed);
        self.hdfs.physical.fetch_add(
            self.written * self.hdfs.replication as u64,
            Ordering::Relaxed,
        );
        Ok(())
    }

    fn abort(mut self: Box<Self>) -> Result<()> {
        self.cleanup();
        Ok(())
    }
}

impl ObjectStore for HdfsLike {
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
        let node = self
            .find_replica(key)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        if node == self.local_node {
            self.local_reads.fetch_add(1, Ordering::Relaxed);
        } else {
            self.remote_reads.fetch_add(1, Ordering::Relaxed);
        }
        let path = self.replica_path(key, node);
        let file = fs::File::open(&path).map_err(|e| Error::io(&path, e))?;
        let size = file.metadata().map_err(|e| Error::io(&path, e))?.len();
        Ok(Box::new(HdfsReader {
            hdfs: self,
            path,
            file: Mutex::new(file),
            size,
        }))
    }

    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
        let nodes = self.replica_nodes(key);
        let token = HDFS_WRITER_SEQ.fetch_add(1, Ordering::Relaxed);
        let mut w = HdfsWriter {
            hdfs: self,
            key: key.to_string(),
            nodes,
            files: Vec::new(),
            token,
            written: 0,
            coalesce: self.append_coalesce,
            carry: Vec::new(),
            finished: false,
        };
        for i in 0..w.nodes.len() {
            let path = w.tmp_path(w.nodes[i]);
            let f = fs::OpenOptions::new()
                .write(true)
                .create(true)
                .truncate(true)
                .open(&path)
                .map_err(|e| Error::io(&path, e))?;
            w.files.push(f);
        }
        Ok(Box::new(w))
    }

    fn stat(&self, key: &str) -> Result<ObjectMeta> {
        let node = self
            .find_replica(key)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        let path = self.replica_path(key, node);
        Ok(ObjectMeta {
            key: key.to_string(),
            size: fs::metadata(&path).map_err(|e| Error::io(&path, e))?.len(),
        })
    }

    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        let replicas = self.replica_nodes(key);
        let paths: Vec<PathBuf> = replicas
            .iter()
            .map(|&n| self.replica_path(key, n))
            .collect();
        let payload: Arc<(Vec<PathBuf>, Vec<u8>)> = Arc::new((paths, data.to_vec()));
        let p2 = Arc::clone(&payload);
        // synchronous pipeline: all replicas must land (Hadoop default)
        let results = self
            .pool
            .map(payload.0.len(), move |i| {
                let path = &p2.0[i];
                fs::write(path, &p2.1).map_err(|e| Error::io(path, e))
            })
            .map_err(Error::Job)?;
        for r in results {
            r?;
        }
        self.logical.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.physical
            .fetch_add((data.len() * self.replication) as u64, Ordering::Relaxed);
        Ok(())
    }

    fn read(&self, key: &str) -> Result<Vec<u8>> {
        let node = self
            .find_replica(key)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        if node == self.local_node {
            self.local_reads.fetch_add(1, Ordering::Relaxed);
        } else {
            self.remote_reads.fetch_add(1, Ordering::Relaxed);
        }
        let path = self.replica_path(key, node);
        let data = fs::read(&path).map_err(|e| Error::io(&path, e))?;
        self.read_bytes.fetch_add(data.len() as u64, Ordering::Relaxed);
        Ok(data)
    }

    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        let node = self
            .find_replica(key)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        if node == self.local_node {
            self.local_reads.fetch_add(1, Ordering::Relaxed);
        } else {
            self.remote_reads.fetch_add(1, Ordering::Relaxed);
        }
        let path = self.replica_path(key, node);
        let mut f = fs::File::open(&path).map_err(|e| Error::io(&path, e))?;
        let size = f.metadata().map_err(|e| Error::io(&path, e))?.len();
        let end = (offset + len as u64).min(size);
        if offset >= end {
            return Ok(Vec::new());
        }
        f.seek(SeekFrom::Start(offset)).map_err(|e| Error::io(&path, e))?;
        let mut buf = vec![0u8; (end - offset) as usize];
        f.read_exact(&mut buf).map_err(|e| Error::io(&path, e))?;
        self.read_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(buf)
    }

    fn size(&self, key: &str) -> Result<u64> {
        let node = self
            .find_replica(key)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        let path = self.replica_path(key, node);
        Ok(fs::metadata(&path).map_err(|e| Error::io(&path, e))?.len())
    }

    fn exists(&self, key: &str) -> bool {
        self.find_replica(key).is_some()
    }

    fn delete(&self, key: &str) -> Result<()> {
        for n in 0..self.node_dirs.len() {
            let _ = fs::remove_file(self.replica_path(key, n));
        }
        Ok(())
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys = std::collections::BTreeSet::new();
        for dir in &self.node_dirs {
            if let Ok(entries) = fs::read_dir(dir) {
                for e in entries.flatten() {
                    let name = e.file_name().to_string_lossy().into_owned();
                    if let Some(enc) = name.strip_suffix(".blk") {
                        let key = enc.replace("%2F", "/").replace("%25", "%");
                        if key.starts_with(prefix) {
                            keys.insert(key);
                        }
                    }
                }
            }
        }
        keys.into_iter().collect()
    }

    fn kind(&self) -> &'static str {
        "hdfs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;

    #[test]
    fn coalescing_writer_matches_append_through() {
        let dir = TempDir::new("hdfs-co").unwrap();
        let mut h = HdfsLike::open(dir.path(), 4, 2).unwrap();
        h.append_coalesce = 128;
        let data: Vec<u8> = (0..3000u32).map(|i| (i % 251) as u8).collect();
        let mut w = h.create("co").unwrap();
        for chunk in data.chunks(17) {
            w.append(chunk).unwrap();
        }
        assert_eq!(w.written(), 3000, "written() must include the carry");
        w.commit().unwrap();
        assert_eq!(h.read("co").unwrap(), data);
        // both replicas hold the complete object
        let copies = (0..4)
            .filter(|&n| h.replica_path("co", n).exists())
            .count();
        assert_eq!(copies, 2);

        // vectored form lands identically
        let parts: Vec<&[u8]> = data.chunks(23).collect();
        let mut w = h.create("vec").unwrap();
        w.append_vectored(&parts).unwrap();
        w.commit().unwrap();
        assert_eq!(h.read("vec").unwrap(), data);

        // abort with a loaded carry leaves no temp debris
        let mut w = h.create("ab").unwrap();
        w.append(&data[..100]).unwrap();
        w.abort().unwrap();
        assert!(!h.exists("ab"));
        for n in 0..4 {
            let leftovers = fs::read_dir(dir.path().join(format!("node{n}")))
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().contains(".tmp-"))
                .count();
            assert_eq!(leftovers, 0, "node {n} holds temp debris");
        }
    }

    #[test]
    fn write_creates_replicas() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 5, 3).unwrap();
        h.write("obj", b"payload").unwrap();
        let copies = (0..5)
            .filter(|&n| h.replica_path("obj", n).exists())
            .count();
        assert_eq!(copies, 3);
        // primary is local
        assert!(h.replica_path("obj", 0).exists());
        let s = h.stats();
        assert_eq!(s.bytes_written_logical, 7);
        assert_eq!(s.bytes_written_physical, 21);
    }

    #[test]
    fn replication_clamped_to_nodes() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 2, 3).unwrap();
        assert_eq!(h.replication(), 2);
        h.write("o", b"x").unwrap();
    }

    #[test]
    fn read_prefers_local_replica() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 4, 2).unwrap();
        h.write("a", b"data").unwrap();
        assert_eq!(h.read("a").unwrap(), b"data");
        let s = h.stats();
        assert_eq!((s.local_reads, s.remote_reads), (1, 0));
    }

    #[test]
    fn remote_read_counted_when_local_missing() {
        let dir = TempDir::new("hdfs").unwrap();
        let mut h = HdfsLike::open(dir.path(), 4, 2).unwrap();
        h.write("a", b"data").unwrap();
        // remove the local copy → read must go "remote"
        fs::remove_file(h.replica_path("a", 0)).unwrap();
        assert_eq!(h.read("a").unwrap(), b"data");
        assert_eq!(h.stats().remote_reads, 1);
        // a different local node also reads remotely
        h.local_node = 3;
        let _ = h.read("a");
        assert!(h.stats().remote_reads >= 1);
    }

    #[test]
    fn replica_placement_deterministic_and_distinct() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 8, 3).unwrap();
        let a = h.replica_nodes("some/object");
        let b = h.replica_nodes("some/object");
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 3, "replicas must be on distinct nodes");
    }

    #[test]
    fn read_range_and_size() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 3, 2).unwrap();
        h.write("r", b"0123456789").unwrap();
        assert_eq!(h.read_range("r", 3, 4).unwrap(), b"3456");
        assert_eq!(h.read_range("r", 8, 100).unwrap(), b"89");
        assert_eq!(h.read_range("r", 20, 5).unwrap(), b"");
        assert_eq!(h.size("r").unwrap(), 10);
    }

    #[test]
    fn delete_removes_all_replicas() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 4, 3).unwrap();
        h.write("d", b"x").unwrap();
        h.delete("d").unwrap();
        assert!(!h.exists("d"));
        for n in 0..4 {
            assert!(!h.replica_path("d", n).exists());
        }
    }

    #[test]
    fn list_dedups_across_replicas() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 4, 3).unwrap();
        h.write("in/p0", b"a").unwrap();
        h.write("in/p1", b"b").unwrap();
        assert_eq!(h.list("in/"), vec!["in/p0", "in/p1"]);
    }

    #[test]
    fn missing_object_errors() {
        let dir = TempDir::new("hdfs").unwrap();
        let h = HdfsLike::open(dir.path(), 2, 1).unwrap();
        assert!(matches!(h.read("ghost"), Err(Error::NotFound(_))));
    }

    // -- v2 handle surface ------------------------------------------------

    #[test]
    fn streaming_writer_replicates_every_chunk() {
        let dir = TempDir::new("hdfs-w").unwrap();
        let h = HdfsLike::open(dir.path(), 5, 3).unwrap();
        let mut w = h.create("obj").unwrap();
        w.append(b"chunk-one ").unwrap();
        // invisible (and unreplicated) until commit
        assert!(!h.exists("obj"));
        w.append(b"chunk-two").unwrap();
        w.commit().unwrap();
        let copies = (0..5)
            .filter(|&n| h.replica_path("obj", n).exists())
            .count();
        assert_eq!(copies, 3, "all replicas land on commit");
        assert_eq!(h.read("obj").unwrap(), b"chunk-one chunk-two");
        let s = h.stats();
        assert_eq!(s.bytes_written_logical, 19);
        assert_eq!(s.bytes_written_physical, 57);
    }

    #[test]
    fn writer_abort_leaves_no_replicas_or_temps() {
        let dir = TempDir::new("hdfs-a").unwrap();
        let h = HdfsLike::open(dir.path(), 3, 2).unwrap();
        let mut w = h.create("gone").unwrap();
        w.append(b"data").unwrap();
        w.abort().unwrap();
        assert!(!h.exists("gone"));
        for n in 0..3 {
            let count = fs::read_dir(dir.path().join(format!("node{n}")))
                .unwrap()
                .count();
            assert_eq!(count, 0, "node {n} must hold no files after abort");
        }
    }

    #[test]
    fn reader_read_at_clamps_and_counts_locality_once() {
        let dir = TempDir::new("hdfs-r").unwrap();
        let h = HdfsLike::open(dir.path(), 3, 2).unwrap();
        h.write("r", b"0123456789").unwrap();
        let r = h.open("r").unwrap();
        assert_eq!(h.stats().local_reads, 1, "locality accounted at open");
        assert_eq!(r.len(), 10);
        let mut buf = [0u8; 4];
        assert_eq!(r.read_at(3, &mut buf).unwrap(), 4);
        assert_eq!(&buf, b"3456");
        assert_eq!(r.read_at(8, &mut buf).unwrap(), 2, "EOF clamp");
        assert_eq!(&buf[..2], b"89");
        assert_eq!(r.read_at(10, &mut buf).unwrap(), 0);
        assert_eq!(h.stats().local_reads, 1, "read_at adds no locality events");
    }

    // -- crash recovery ----------------------------------------------------

    #[test]
    fn recover_on_clean_store_is_clean() {
        let dir = TempDir::new("hdfs-rec0").unwrap();
        let h = HdfsLike::open(dir.path(), 4, 3).unwrap();
        h.write("a", b"payload").unwrap();
        let report = h.recover_hdfs().unwrap();
        assert!(report.is_clean(), "{report}");
    }

    #[test]
    fn recover_removes_temp_replicas() {
        let dir = TempDir::new("hdfs-rec1").unwrap();
        let h = HdfsLike::open(dir.path(), 3, 2).unwrap();
        h.write("live", b"data").unwrap();
        fs::write(dir.path().join("node0").join("k.blk.tmp-9"), b"junk").unwrap();
        fs::write(dir.path().join("node2").join("k.blk.tmp-9"), b"junk").unwrap();
        let report = h.recover_hdfs().unwrap();
        assert_eq!(report.temps_removed, 2, "{report}");
        assert!(!h.exists("k"));
        assert_eq!(h.read("live").unwrap(), b"data");
    }

    #[test]
    fn recover_restores_lost_replicas() {
        let dir = TempDir::new("hdfs-rec2").unwrap();
        let h = HdfsLike::open(dir.path(), 5, 3).unwrap();
        h.write("obj", b"replicate me").unwrap();
        // lose one replica (disk death)
        let nodes = h.replica_nodes("obj");
        fs::remove_file(h.replica_path("obj", nodes[1])).unwrap();
        let report = h.recover_hdfs().unwrap();
        assert_eq!(report.repaired, vec!["obj".to_string()], "{report}");
        let copies = (0..5)
            .filter(|&n| h.replica_path("obj", n).exists())
            .count();
        assert_eq!(copies, 3, "full replication restored");
        assert_eq!(h.read("obj").unwrap(), b"replicate me");
    }

    #[test]
    fn recover_heals_divergent_replicas_to_one_version() {
        let dir = TempDir::new("hdfs-rec3").unwrap();
        let h = HdfsLike::open(dir.path(), 4, 3).unwrap();
        h.write("obj", b"version-one").unwrap();
        // a crashed overwrite commit left one replica on the new version
        let nodes = h.replica_nodes("obj");
        let mut sorted = nodes.clone();
        sorted.sort_unstable();
        // diverge a replica that is NOT the lowest-numbered one (the
        // elected source), so healing rewrites it back
        fs::write(h.replica_path("obj", sorted[1]), b"version-TWO").unwrap();
        let report = h.recover_hdfs().unwrap();
        assert_eq!(report.repaired, vec!["obj".to_string()]);
        // every replica now serves the elected version
        for &n in &nodes {
            assert_eq!(fs::read(h.replica_path("obj", n)).unwrap(), b"version-one");
        }
        // second pass is clean
        assert!(h.recover_hdfs().unwrap().is_clean());
    }
}
