//! Block abstraction: fixed-size logical blocks with checksums.
//!
//! The paper (§3.1, Figure 3): "an input file is stored in Tachyon as a set
//! of fixed size logical blocks"; the PFS side stores stripes. This module
//! owns the block math shared by the memory tier and the layout mapper.

use crate::error::{Error, Result};

/// Identifies one logical block of an object.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId {
    /// Object key the block belongs to.
    pub object: String,
    /// Zero-based block index within the object.
    pub index: u64,
}

impl BlockId {
    /// Block `index` of `object`.
    pub fn new(object: impl Into<String>, index: u64) -> Self {
        Self {
            object: object.into(),
            index,
        }
    }

    /// Canonical storage key (used as the memstore map key).
    pub fn storage_key(&self) -> String {
        format!("{}#{}", self.object, self.index)
    }
}

/// Geometry of an object split into fixed-size blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockGeometry {
    /// Full object size in bytes.
    pub object_size: u64,
    /// Block size in bytes.
    pub block_size: u64,
}

impl BlockGeometry {
    /// A geometry; errors if `block_size` is zero.
    pub fn new(object_size: u64, block_size: u64) -> Result<Self> {
        if block_size == 0 {
            return Err(Error::InvalidArg("block_size must be > 0".into()));
        }
        Ok(Self {
            object_size,
            block_size,
        })
    }

    /// Number of blocks (last may be partial). Zero-byte objects still
    /// occupy zero blocks.
    pub fn num_blocks(&self) -> u64 {
        self.object_size.div_ceil(self.block_size)
    }

    /// Size of block `i`.
    pub fn block_len(&self, i: u64) -> u64 {
        debug_assert!(i < self.num_blocks() || self.object_size == 0);
        let start = i * self.block_size;
        (self.object_size - start).min(self.block_size)
    }

    /// Byte range `[start, end)` of block `i` within the object.
    pub fn block_range(&self, i: u64) -> (u64, u64) {
        let start = i * self.block_size;
        (start, start + self.block_len(i))
    }

    /// Which blocks overlap the byte range `[offset, offset+len)`, clamped
    /// to the object, with the in-block sub-ranges.
    pub fn blocks_for_range(&self, offset: u64, len: u64) -> Vec<(u64, u64, u64)> {
        let end = (offset + len).min(self.object_size);
        if offset >= end {
            return Vec::new();
        }
        let first = offset / self.block_size;
        let last = (end - 1) / self.block_size;
        (first..=last)
            .map(|i| {
                let (bs, be) = self.block_range(i);
                let s = offset.max(bs) - bs;
                let e = end.min(be) - bs;
                (i, s, e)
            })
            .collect()
    }
}

/// Streaming IEEE CRC-32 accumulator, shared with the cluster plane's
/// frame trailer — the single implementation lives in
/// [`crate::util::crc32`]; this re-export keeps the storage tier's
/// historical import path working.
pub use crate::util::crc32::Crc32;

/// CRC32 checksum of a block (the PFS tier verifies on read; the paper's
/// data-node-level erasure coding is out of scope, per-block CRC gives the
/// equivalent corruption *detection* signal). Delegates to the tree's one
/// CRC implementation in [`crate::util::crc32`].
pub use crate::util::crc32::checksum;

/// Verify `data` against `stored`, or return [`Error::ChecksumMismatch`].
pub fn verify_checksum(object: &str, data: &[u8], stored: u32) -> Result<()> {
    let computed = checksum(data);
    if computed != stored {
        return Err(Error::ChecksumMismatch {
            object: object.to_string(),
            stored,
            computed,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_key_is_unique_per_index() {
        assert_ne!(
            BlockId::new("a", 0).storage_key(),
            BlockId::new("a", 1).storage_key()
        );
        assert_eq!(BlockId::new("x/y", 3).storage_key(), "x/y#3");
    }

    #[test]
    fn geometry_block_counts() {
        let g = BlockGeometry::new(100, 40).unwrap();
        assert_eq!(g.num_blocks(), 3);
        assert_eq!(g.block_len(0), 40);
        assert_eq!(g.block_len(1), 40);
        assert_eq!(g.block_len(2), 20);
        assert_eq!(g.block_range(2), (80, 100));
    }

    #[test]
    fn geometry_exact_multiple() {
        let g = BlockGeometry::new(80, 40).unwrap();
        assert_eq!(g.num_blocks(), 2);
        assert_eq!(g.block_len(1), 40);
    }

    #[test]
    fn geometry_empty_object() {
        let g = BlockGeometry::new(0, 40).unwrap();
        assert_eq!(g.num_blocks(), 0);
        assert!(g.blocks_for_range(0, 10).is_empty());
    }

    #[test]
    fn geometry_rejects_zero_block() {
        assert!(BlockGeometry::new(10, 0).is_err());
    }

    #[test]
    fn blocks_for_range_spans() {
        let g = BlockGeometry::new(100, 40).unwrap();
        // range [30, 90) touches blocks 0 (30..40), 1 (0..40), 2 (0..10)
        assert_eq!(
            g.blocks_for_range(30, 60),
            vec![(0, 30, 40), (1, 0, 40), (2, 0, 10)]
        );
        // clamped at EOF
        assert_eq!(g.blocks_for_range(95, 1000), vec![(2, 15, 20)]);
        // empty past EOF
        assert!(g.blocks_for_range(100, 5).is_empty());
        assert!(g.blocks_for_range(40, 0).is_empty());
    }

    #[test]
    fn checksum_detects_flip() {
        let data = b"The quick brown fox".to_vec();
        let c = checksum(&data);
        verify_checksum("obj", &data, c).unwrap();
        let mut bad = data.clone();
        bad[3] ^= 1;
        let err = verify_checksum("obj", &bad, c).unwrap_err();
        assert!(matches!(err, Error::ChecksumMismatch { .. }));
    }

    #[test]
    fn checksum_known_value() {
        // IEEE CRC32 of "123456789" is 0xCBF43926
        assert_eq!(checksum(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn streaming_crc_matches_one_shot_for_any_split() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let whole = checksum(&data);
        for chunk in [1usize, 3, 7, 64, 999, 1000, 2000] {
            let mut c = Crc32::new();
            for piece in data.chunks(chunk) {
                c.update(piece);
            }
            assert_eq!(c.finish(), whole, "chunk={chunk}");
        }
        // empty stream == checksum of empty slice
        assert_eq!(Crc32::new().finish(), checksum(b""));
    }
}
