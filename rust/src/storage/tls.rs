//! The two-level storage system — the paper's contribution (§3).
//!
//! Composition: an in-memory block tier ([`MemStore`], the paper's
//! Tachyon) over a striped parallel-FS tier ([`Pfs`], the paper's
//! OrangeFS), glued by:
//!
//! - the three **write modes** and three **read modes** of Figure 4
//!   ([`WriteMode`], [`ReadMode`]),
//! - the **block ↔ stripe layout mapping** of Figure 3 (objects live in
//!   the memory tier as `block_size` logical blocks and on the PFS as a
//!   striped checkpoint file),
//! - the dual **I/O buffers** of §3.2 (`app_buffer` between application
//!   and memory tier, `pfs_buffer` between the tiers) — write-through
//!   drives both legs **concurrently** (`concurrent_writethrough`), one
//!   scoped thread feeding the lock-striped memory tier
//!   (`mem_shards`, see [`MemStore::with_shards`]) while the caller
//!   drives the striped PFS write, which fans out one task per server,
//! - the **priority-based read policy** of §3.2: every block read goes to
//!   the nearest tier that has it (memory first, then PFS), and two-level
//!   reads cache what they fetched, subject to LRU/LFU eviction.
//!
//! Mode-(a) writes leave *dirty* blocks that exist only in memory; if
//! eviction pushes a dirty block out, it is checkpointed to a per-block
//! PFS object first (the safety net standing in for Tachyon's lineage),
//! and [`TwoLevelStore::checkpoint`] consolidates an object into its
//! striped PFS file (what the paper's synchronous mode (c) does inline).

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::storage::block::{BlockGeometry, BlockId};
use crate::storage::memstore::{MemStats, MemStore};
use crate::storage::pfs::{Pfs, PfsStats};
use crate::storage::{ObjectStore, ReadMode, WriteMode};
use crate::util::pool::ThreadPool;

/// Namespace prefix for dirty-block spill objects on the PFS.
const DIRTY_NS: &str = ".dirty/";
/// Marker file pinning the block size of a store root.
const GEOMETRY_MARKER: &str = ".tls-geometry";

/// Configuration for [`TwoLevelStore`].
#[derive(Debug, Clone)]
pub struct TlsConfig {
    pub root: PathBuf,
    pub mem_capacity: u64,
    pub block_size: u64,
    pub pfs_servers: usize,
    pub stripe_size: u64,
    pub app_buffer: u64,
    pub pfs_buffer: u64,
    pub eviction: String,
    pub workers: usize,
    /// Lock stripes of the memory tier (see
    /// [`MemStore::with_shards`]); `1` reproduces the single-mutex
    /// baseline the fig1 bench compares against.
    pub mem_shards: usize,
    /// Issue the memory-tier and PFS legs of a
    /// [`WriteMode::WriteThrough`] concurrently through the two §3.2
    /// buffers (`false` reproduces the sequential baseline).
    pub concurrent_writethrough: bool,
}

impl TlsConfig {
    /// Builder with the paper's §3.2 buffer defaults.
    pub fn builder(root: impl Into<PathBuf>) -> TlsConfigBuilder {
        TlsConfigBuilder {
            cfg: TlsConfig {
                root: root.into(),
                mem_capacity: 256 << 20,
                block_size: 4 << 20,
                pfs_servers: 4,
                stripe_size: 1 << 20,
                app_buffer: 1 << 20,
                pfs_buffer: 4 << 20,
                eviction: "lru".into(),
                workers: 4,
                mem_shards: crate::config::presets::tuning::default_mem_shards(),
                concurrent_writethrough: true,
            },
        }
    }

    /// Derive from an [`crate::config::EngineConfig`].
    pub fn from_engine(e: &crate::config::EngineConfig) -> Self {
        Self {
            root: e.root.clone(),
            mem_capacity: e.mem_capacity,
            block_size: e.block_size,
            pfs_servers: e.pfs_servers,
            stripe_size: e.stripe_size,
            app_buffer: e.app_buffer,
            pfs_buffer: e.pfs_buffer,
            eviction: e.eviction.clone(),
            workers: e.workers,
            mem_shards: e.mem_shards,
            concurrent_writethrough: e.concurrent_writethrough,
        }
    }
}

/// Fluent builder for [`TlsConfig`].
pub struct TlsConfigBuilder {
    cfg: TlsConfig,
}

impl TlsConfigBuilder {
    pub fn mem_capacity(mut self, v: u64) -> Self {
        self.cfg.mem_capacity = v;
        self
    }
    pub fn block_size(mut self, v: u64) -> Self {
        self.cfg.block_size = v;
        self
    }
    pub fn pfs_servers(mut self, v: usize) -> Self {
        self.cfg.pfs_servers = v;
        self
    }
    pub fn stripe_size(mut self, v: u64) -> Self {
        self.cfg.stripe_size = v;
        self
    }
    pub fn app_buffer(mut self, v: u64) -> Self {
        self.cfg.app_buffer = v;
        self
    }
    pub fn pfs_buffer(mut self, v: u64) -> Self {
        self.cfg.pfs_buffer = v;
        self
    }
    pub fn eviction(mut self, v: &str) -> Self {
        self.cfg.eviction = v.into();
        self
    }
    pub fn workers(mut self, v: usize) -> Self {
        self.cfg.workers = v;
        self
    }
    pub fn mem_shards(mut self, v: usize) -> Self {
        self.cfg.mem_shards = v;
        self
    }
    pub fn concurrent_writethrough(mut self, v: bool) -> Self {
        self.cfg.concurrent_writethrough = v;
        self
    }
    pub fn build(self) -> Result<TlsConfig> {
        let c = &self.cfg;
        if c.block_size == 0 || c.stripe_size == 0 || c.app_buffer == 0 || c.pfs_buffer == 0 {
            return Err(Error::Config("sizes must be > 0".into()));
        }
        if c.pfs_servers == 0 {
            return Err(Error::Config("pfs_servers must be > 0".into()));
        }
        if c.mem_shards == 0 {
            return Err(Error::Config("mem_shards must be > 0".into()));
        }
        Ok(self.cfg)
    }
}

#[derive(Debug, Clone)]
struct ObjEntry {
    size: u64,
    /// Whole-object striped checkpoint exists on the PFS.
    persisted: bool,
}

/// Tier-level counters for the Figure-6 / ablation measurements.
#[derive(Debug, Clone, Copy, Default)]
pub struct TlsStats {
    /// Bytes served from the memory tier.
    pub mem_bytes_read: u64,
    /// Bytes served from the PFS tier.
    pub pfs_bytes_read: u64,
    /// Dirty blocks spilled by eviction pressure.
    pub dirty_spills: u64,
    /// Whole-object checkpoints written.
    pub checkpoints: u64,
}

impl TlsStats {
    /// Measured fraction of reads served by the memory tier — the paper's
    /// `f` parameter, observed.
    pub fn f_ratio(&self) -> f64 {
        let total = self.mem_bytes_read + self.pfs_bytes_read;
        if total == 0 {
            0.0
        } else {
            self.mem_bytes_read as f64 / total as f64
        }
    }
}

/// The two-level store.
pub struct TwoLevelStore {
    cfg: TlsConfig,
    mem: MemStore,
    pfs: Pfs,
    objects: Mutex<HashMap<String, ObjEntry>>,
    dirty: Mutex<HashSet<String>>, // storage_key of dirty blocks
    mem_bytes_read: AtomicU64,
    pfs_bytes_read: AtomicU64,
    dirty_spills: AtomicU64,
    checkpoints: AtomicU64,
}

impl TwoLevelStore {
    /// Open (or create) a store. Re-opening a root recovers persisted
    /// objects from the PFS tier; the memory tier starts cold, exactly
    /// like a Tachyon restart over OrangeFS.
    pub fn open(cfg: TlsConfig) -> Result<Self> {
        let pool = Arc::new(ThreadPool::new(cfg.workers.max(2)));
        let pfs = Pfs::open_with_pool(
            &cfg.root.join("pfs"),
            cfg.pfs_servers,
            cfg.stripe_size,
            pool,
        )?;
        Self::check_geometry_marker(&cfg)?;
        let mem = MemStore::with_shards(cfg.mem_capacity, &cfg.eviction, cfg.mem_shards)?;

        // Recover the object table from PFS contents.
        let mut objects = HashMap::new();
        for key in pfs.list("") {
            if key.starts_with(DIRTY_NS) {
                // spilled block of an unpersisted object
                if let Some((obj, _idx)) = key[DIRTY_NS.len()..].rsplit_once('#') {
                    objects
                        .entry(obj.to_string())
                        .or_insert(ObjEntry {
                            size: 0,
                            persisted: false,
                        });
                }
                continue;
            }
            let size = pfs.size(&key)?;
            objects.insert(
                key,
                ObjEntry {
                    size,
                    persisted: true,
                },
            );
        }

        Ok(Self {
            cfg,
            mem,
            pfs,
            objects: Mutex::new(objects),
            dirty: Mutex::new(HashSet::new()),
            mem_bytes_read: AtomicU64::new(0),
            pfs_bytes_read: AtomicU64::new(0),
            dirty_spills: AtomicU64::new(0),
            checkpoints: AtomicU64::new(0),
        })
    }

    fn check_geometry_marker(cfg: &TlsConfig) -> Result<()> {
        let marker = cfg.root.join(GEOMETRY_MARKER);
        match std::fs::read_to_string(&marker) {
            Ok(text) => {
                let stored: u64 = text
                    .trim()
                    .parse()
                    .map_err(|_| Error::Config("corrupt geometry marker".into()))?;
                if stored != cfg.block_size {
                    return Err(Error::Config(format!(
                        "store was created with block_size {stored}, reopened with {}",
                        cfg.block_size
                    )));
                }
                Ok(())
            }
            Err(_) => {
                std::fs::create_dir_all(&cfg.root).map_err(|e| Error::io(&cfg.root, e))?;
                std::fs::write(&marker, cfg.block_size.to_string())
                    .map_err(|e| Error::io(&marker, e))?;
                Ok(())
            }
        }
    }

    pub fn config(&self) -> &TlsConfig {
        &self.cfg
    }

    pub fn mem_stats(&self) -> MemStats {
        self.mem.stats()
    }

    pub fn pfs_stats(&self) -> PfsStats {
        self.pfs.stats()
    }

    pub fn stats(&self) -> TlsStats {
        TlsStats {
            mem_bytes_read: self.mem_bytes_read.load(Ordering::Relaxed),
            pfs_bytes_read: self.pfs_bytes_read.load(Ordering::Relaxed),
            dirty_spills: self.dirty_spills.load(Ordering::Relaxed),
            checkpoints: self.checkpoints.load(Ordering::Relaxed),
        }
    }

    /// Direct access to the PFS tier (the coordinator and benches use it).
    pub fn pfs(&self) -> &Pfs {
        &self.pfs
    }

    /// Direct access to the memory tier.
    pub fn mem(&self) -> &MemStore {
        &self.mem
    }

    fn geometry(&self, size: u64) -> BlockGeometry {
        BlockGeometry::new(size, self.cfg.block_size).expect("validated block size")
    }

    fn dirty_key(object: &str, index: u64) -> String {
        format!("{DIRTY_NS}{object}#{index}")
    }

    /// Handle eviction victims: dirty blocks must hit the PFS before the
    /// bytes disappear (the safety net standing in for Tachyon lineage).
    fn spill_evicted(&self, evicted: Vec<(String, Arc<[u8]>)>) -> Result<()> {
        if evicted.is_empty() {
            return Ok(());
        }
        let mut dirty = self.dirty.lock().unwrap();
        for (key, bytes) in evicted {
            if dirty.remove(&key) {
                let (obj, idx) = key.rsplit_once('#').expect("storage key format");
                self.pfs
                    .write(&Self::dirty_key(obj, idx.parse().unwrap()), &bytes)?;
                self.dirty_spills.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Insert blocks into the memory tier, spilling dirty victims.
    fn put_blocks(&self, object: &str, data: &[u8], mark_dirty: bool) -> Result<()> {
        let geo = self.geometry(data.len() as u64);
        for i in 0..geo.num_blocks() {
            let (s, e) = geo.block_range(i);
            let bytes: Arc<[u8]> = data[s as usize..e as usize].to_vec().into();
            let key = BlockId::new(object, i).storage_key();
            if mark_dirty {
                self.dirty.lock().unwrap().insert(key.clone());
            }
            let evicted = self.mem.put(&key, bytes)?;
            self.spill_evicted(evicted)?;
        }
        Ok(())
    }

    /// Write under an explicit mode (Figure 4 a–c).
    pub fn write(&self, key: &str, data: &[u8], mode: WriteMode) -> Result<()> {
        if key.starts_with('.') {
            return Err(Error::InvalidArg(
                "keys starting with '.' are reserved".into(),
            ));
        }
        match mode {
            WriteMode::MemOnly => {
                // a block bigger than the memory tier can never be MemOnly
                if self.cfg.block_size.min(data.len() as u64) > self.cfg.mem_capacity {
                    return Err(Error::OverCapacity {
                        need: data.len() as u64,
                        capacity: self.cfg.mem_capacity,
                    });
                }
                self.put_blocks(key, data, true)?;
                self.objects.lock().unwrap().insert(
                    key.to_string(),
                    ObjEntry {
                        size: data.len() as u64,
                        persisted: false,
                    },
                );
            }
            WriteMode::Bypass => {
                self.pfs.write(key, data)?;
                self.objects.lock().unwrap().insert(
                    key.to_string(),
                    ObjEntry {
                        size: data.len() as u64,
                        persisted: true,
                    },
                );
            }
            WriteMode::WriteThrough => {
                // §4, eq. (6): synchronous write to both tiers; throughput
                // bounded by the PFS (the slower leg). The two legs ride
                // the two §3.2 buffers independently, so they are issued
                // concurrently: one scoped thread feeds the memory tier
                // while this thread drives the striped PFS write (which
                // itself fans out per server). Per-block over-capacity is
                // pre-checked so the failure mode matches the sequential
                // path (no PFS write happens when the mem leg cannot fit
                // a single block).
                if !data.is_empty()
                    && self.cfg.block_size.min(data.len() as u64) > self.cfg.mem_capacity
                {
                    return Err(Error::OverCapacity {
                        need: data.len() as u64,
                        capacity: self.cfg.mem_capacity,
                    });
                }
                // `pfs_ran` distinguishes "PFS leg executed" (always, in
                // the concurrent fork) from the sequential path, which
                // never starts it after a mem-leg failure.
                let (mem_res, pfs_res, pfs_ran) = if self.cfg.concurrent_writethrough {
                    let (m, p) = std::thread::scope(|s| {
                        let mem_leg = s.spawn(|| self.put_blocks(key, data, false));
                        let pfs_res = self.pfs.write(key, data);
                        (
                            mem_leg.join().expect("memory-tier write leg panicked"),
                            pfs_res,
                        )
                    });
                    (m, p, true)
                } else {
                    match self.put_blocks(key, data, false) {
                        Err(e) => (Err(e), Ok(()), false),
                        Ok(()) => (Ok(()), self.pfs.write(key, data), true),
                    }
                };
                if pfs_ran && pfs_res.is_err() {
                    // The PFS leg failed: roll this key's freshly cached
                    // blocks out of the memory tier so a write that
                    // returned Err is never served from cache (readers
                    // fall back to whatever the PFS holds).
                    let geo = self.geometry(data.len() as u64);
                    for i in 0..geo.num_blocks() {
                        self.mem.remove(&BlockId::new(key, i).storage_key());
                    }
                } else if pfs_ran && mem_res.is_err() {
                    // PFS leg landed, mem leg failed. For a fresh key,
                    // remove the orphan so a restart's PFS recovery cannot
                    // resurrect a write that returned Err — matching the
                    // sequential path. For a previously persisted key the
                    // old bytes are already overwritten in place and
                    // cannot be restored; commit the fully landed new
                    // object so reads stay self-consistent instead of
                    // mixing the stale size with the new PFS contents.
                    let old_entry = self.objects.lock().unwrap().get(key).cloned();
                    match old_entry {
                        Some(old) if old.persisted => {
                            // Purge every cached block of either version
                            // first: the failed mem leg may have stopped
                            // partway, leaving stale old-version blocks
                            // that the new geometry would happily serve.
                            let max_size = old.size.max(data.len() as u64);
                            let geo = self.geometry(max_size);
                            for i in 0..geo.num_blocks() {
                                self.mem.remove(&BlockId::new(key, i).storage_key());
                            }
                            self.objects.lock().unwrap().insert(
                                key.to_string(),
                                ObjEntry {
                                    size: data.len() as u64,
                                    persisted: true,
                                },
                            );
                        }
                        _ => {
                            let _ = self.pfs.delete(key);
                        }
                    }
                }
                mem_res?;
                pfs_res?;
                self.objects.lock().unwrap().insert(
                    key.to_string(),
                    ObjEntry {
                        size: data.len() as u64,
                        persisted: true,
                    },
                );
            }
        }
        Ok(())
    }

    fn entry(&self, key: &str) -> Result<ObjEntry> {
        self.objects
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| Error::NotFound(key.to_string()))
    }

    /// Fetch one block through the priority policy. Returns the bytes and
    /// which tier served them.
    ///
    /// Concurrency: a dirty block evicted by another thread is briefly in
    /// flight between leaving the memory tier and landing in the PFS dirty
    /// namespace (eviction and spill are not one atomic step). The block
    /// is never *lost* — it is in memory, in `.dirty/`, or the object has
    /// just been checkpointed — so a miss on every tier retries with a
    /// fresh object-table snapshot until the in-flight write lands.
    fn read_block(&self, key: &str, index: u64, cache: bool) -> Result<(Arc<[u8]>, bool)> {
        let skey = BlockId::new(key, index).storage_key();
        const MAX_ATTEMPTS: u32 = 500;
        for attempt in 0..MAX_ATTEMPTS {
            if let Some(bytes) = self.mem.get(&skey) {
                return Ok((bytes, true));
            }
            // miss → PFS: prefer the consolidated checkpoint, else spill
            let entry = self.entry(key)?;
            let geo = self.geometry(entry.size);
            let (s, e) = geo.block_range(index);
            let fetched: Result<Vec<u8>> = if entry.persisted {
                // chunked transfer through the §3.2 pfs buffer
                let mut out = Vec::with_capacity((e - s) as usize);
                let mut off = s;
                let mut ok = Ok(());
                while off < e {
                    let chunk = (e - off).min(self.cfg.pfs_buffer);
                    match self.pfs.read_range(key, off, chunk as usize) {
                        Ok(part) => out.extend_from_slice(&part),
                        Err(err) => {
                            ok = Err(err);
                            break;
                        }
                    }
                    off += chunk;
                }
                ok.map(|_| out)
            } else {
                self.pfs.read(&Self::dirty_key(key, index))
            };
            match fetched {
                Ok(bytes) => {
                    let bytes: Arc<[u8]> = bytes.into();
                    if cache {
                        let evicted = self.mem.put(&skey, Arc::clone(&bytes))?;
                        self.spill_evicted(evicted)?;
                    }
                    return Ok((bytes, false));
                }
                // in-flight spill/checkpoint: back off and re-snapshot
                Err(Error::NotFound(_)) if attempt + 1 < MAX_ATTEMPTS => {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => return Err(e),
            }
        }
        Err(Error::NotFound(format!("{key} block {index}: lost")))
    }

    /// Read under an explicit mode (Figure 4 d–f).
    pub fn read(&self, key: &str, mode: ReadMode) -> Result<Vec<u8>> {
        let entry = self.entry(key)?;
        match mode {
            ReadMode::Bypass => {
                if !entry.persisted {
                    return Err(Error::NotFound(format!(
                        "{key}: not persisted; Bypass reads only the PFS tier"
                    )));
                }
                let data = self.pfs.read(key)?;
                self.pfs_bytes_read
                    .fetch_add(data.len() as u64, Ordering::Relaxed);
                Ok(data)
            }
            ReadMode::MemOnly | ReadMode::TwoLevel => {
                let geo = self.geometry(entry.size);
                let mut out = Vec::with_capacity(entry.size as usize);
                for i in 0..geo.num_blocks() {
                    let skey = BlockId::new(key, i).storage_key();
                    let (bytes, from_mem) = match mode {
                        ReadMode::MemOnly => match self.mem.get(&skey) {
                            Some(b) => (b, true),
                            None => {
                                return Err(Error::NotFound(format!(
                                    "{key} block {i}: evicted from memory tier (MemOnly read)"
                                )))
                            }
                        },
                        _ => self.read_block(key, i, true)?,
                    };
                    if from_mem {
                        self.mem_bytes_read
                            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    } else {
                        self.pfs_bytes_read
                            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    }
                    out.extend_from_slice(&bytes);
                }
                Ok(out)
            }
        }
    }

    /// Ranged read under a mode.
    pub fn read_range(&self, key: &str, offset: u64, len: usize, mode: ReadMode) -> Result<Vec<u8>> {
        let entry = self.entry(key)?;
        if matches!(mode, ReadMode::Bypass) {
            if !entry.persisted {
                return Err(Error::NotFound(format!("{key}: not persisted")));
            }
            let data = self.pfs.read_range(key, offset, len)?;
            self.pfs_bytes_read
                .fetch_add(data.len() as u64, Ordering::Relaxed);
            return Ok(data);
        }
        let geo = self.geometry(entry.size);
        let pieces = geo.blocks_for_range(offset, len as u64);
        let mut out = Vec::new();
        for (i, s, e) in pieces {
            let (bytes, from_mem) = match mode {
                ReadMode::MemOnly => {
                    let skey = BlockId::new(key, i).storage_key();
                    match self.mem.get(&skey) {
                        Some(b) => (b, true),
                        None => {
                            return Err(Error::NotFound(format!(
                                "{key} block {i}: not in memory tier"
                            )))
                        }
                    }
                }
                _ => self.read_block(key, i, true)?,
            };
            let served = (e - s) as u64;
            if from_mem {
                self.mem_bytes_read.fetch_add(served, Ordering::Relaxed);
            } else {
                self.pfs_bytes_read.fetch_add(served, Ordering::Relaxed);
            }
            out.extend_from_slice(&bytes[s as usize..e as usize]);
        }
        Ok(out)
    }

    /// Consolidate `key` into its striped whole-object checkpoint on the
    /// PFS (no-op if already persisted). This is what the coordinator's
    /// checkpointer calls for mode-(a) data.
    pub fn checkpoint(&self, key: &str) -> Result<()> {
        let entry = self.entry(key)?;
        if entry.persisted {
            return Ok(());
        }
        let data = self.read(key, ReadMode::TwoLevel)?;
        self.pfs.write(key, &data)?;
        // Flip the object to persisted *before* dropping the spill blocks:
        // concurrent readers that miss memory then re-snapshot the entry
        // and route to the consolidated checkpoint instead of the (soon to
        // vanish) dirty namespace.
        self.objects.lock().unwrap().insert(
            key.to_string(),
            ObjEntry {
                size: entry.size,
                persisted: true,
            },
        );
        let geo = self.geometry(entry.size);
        let mut dirty = self.dirty.lock().unwrap();
        for i in 0..geo.num_blocks() {
            dirty.remove(&BlockId::new(key, i).storage_key());
            let _ = self.pfs.delete(&Self::dirty_key(key, i));
        }
        drop(dirty);
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Keys of objects not yet persisted (the checkpointer's work queue).
    pub fn unpersisted(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .objects
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| !e.persisted)
            .map(|(k, _)| k.clone())
            .collect();
        v.sort();
        v
    }

    /// Evict an object's blocks from the memory tier (for cache-pressure
    /// experiments); dirty blocks are spilled first via checkpoint.
    pub fn evict_object(&self, key: &str) -> Result<()> {
        let entry = self.entry(key)?;
        if !entry.persisted {
            self.checkpoint(key)?;
        }
        let geo = self.geometry(entry.size);
        for i in 0..geo.num_blocks() {
            self.mem.remove(&BlockId::new(key, i).storage_key());
        }
        Ok(())
    }
}

impl ObjectStore for TwoLevelStore {
    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        TwoLevelStore::write(self, key, data, WriteMode::WriteThrough)
    }

    fn read(&self, key: &str) -> Result<Vec<u8>> {
        TwoLevelStore::read(self, key, ReadMode::TwoLevel)
    }

    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        TwoLevelStore::read_range(self, key, offset, len, ReadMode::TwoLevel)
    }

    fn size(&self, key: &str) -> Result<u64> {
        Ok(self.entry(key)?.size)
    }

    fn exists(&self, key: &str) -> bool {
        self.objects.lock().unwrap().contains_key(key)
    }

    fn delete(&self, key: &str) -> Result<()> {
        let entry = match self.entry(key) {
            Ok(e) => e,
            Err(_) => return Ok(()),
        };
        let geo = self.geometry(entry.size);
        let mut dirty = self.dirty.lock().unwrap();
        for i in 0..geo.num_blocks() {
            let skey = BlockId::new(key, i).storage_key();
            self.mem.remove(&skey);
            dirty.remove(&skey);
            let _ = self.pfs.delete(&Self::dirty_key(key, i));
        }
        drop(dirty);
        self.pfs.delete(key)?;
        self.objects.lock().unwrap().remove(key);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .objects
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.sort();
        keys
    }

    fn kind(&self) -> &'static str {
        "tls"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg32;

    fn rand_data(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg32::new(seed, 1);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    fn store(dir: &TempDir, mem_cap: u64, block: u64) -> TwoLevelStore {
        let cfg = TlsConfig::builder(dir.path())
            .mem_capacity(mem_cap)
            .block_size(block)
            .pfs_servers(3)
            .stripe_size(64)
            .pfs_buffer(128)
            .build()
            .unwrap();
        TwoLevelStore::open(cfg).unwrap()
    }

    #[test]
    fn write_through_lands_in_both_tiers() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1000, 1);
        s.write("obj", &data, WriteMode::WriteThrough).unwrap();
        // read (d): memory only — must fully succeed
        assert_eq!(s.read("obj", ReadMode::MemOnly).unwrap(), data);
        // read (e): PFS only — must also succeed
        assert_eq!(s.read("obj", ReadMode::Bypass).unwrap(), data);
    }

    #[test]
    fn mem_only_write_not_on_pfs_until_checkpoint() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(500, 2);
        s.write("hot", &data, WriteMode::MemOnly).unwrap();
        assert!(matches!(s.read("hot", ReadMode::Bypass), Err(Error::NotFound(_))));
        assert_eq!(s.unpersisted(), vec!["hot"]);
        s.checkpoint("hot").unwrap();
        assert_eq!(s.read("hot", ReadMode::Bypass).unwrap(), data);
        assert!(s.unpersisted().is_empty());
        assert_eq!(s.stats().checkpoints, 1);
    }

    #[test]
    fn bypass_write_skips_memory_tier() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(600, 3);
        s.write("cold", &data, WriteMode::Bypass).unwrap();
        assert!(matches!(s.read("cold", ReadMode::MemOnly), Err(Error::NotFound(_))));
        // two-level read pulls it up and caches it
        assert_eq!(s.read("cold", ReadMode::TwoLevel).unwrap(), data);
        assert_eq!(s.read("cold", ReadMode::MemOnly).unwrap(), data);
    }

    #[test]
    fn two_level_read_mixes_tiers_and_tracks_f() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1024, 4);
        s.write("obj", &data, WriteMode::WriteThrough).unwrap();
        // evict half the blocks from memory
        s.mem().remove("obj#0");
        s.mem().remove("obj#1");
        assert_eq!(s.read("obj", ReadMode::TwoLevel).unwrap(), data);
        let st = s.stats();
        assert_eq!(st.mem_bytes_read, 512);
        assert_eq!(st.pfs_bytes_read, 512);
        assert!((st.f_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dirty_blocks_survive_eviction_pressure() {
        let dir = TempDir::new("tls").unwrap();
        // memory fits only 2 blocks of 256
        let s = store(&dir, 512, 256);
        let a = rand_data(512, 5);
        let b = rand_data(512, 6);
        s.write("a", &a, WriteMode::MemOnly).unwrap();
        s.write("b", &b, WriteMode::MemOnly).unwrap(); // evicts a's blocks
        assert!(s.stats().dirty_spills >= 1);
        // 'a' must still be fully readable (spilled blocks come from PFS)
        assert_eq!(s.read("a", ReadMode::TwoLevel).unwrap(), a);
        assert_eq!(s.read("b", ReadMode::TwoLevel).unwrap(), b);
    }

    #[test]
    fn checkpoint_consolidates_spilled_blocks() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 512, 256);
        let a = rand_data(512, 7);
        s.write("a", &a, WriteMode::MemOnly).unwrap();
        s.write("b", &rand_data(512, 8), WriteMode::MemOnly).unwrap();
        s.checkpoint("a").unwrap();
        assert_eq!(s.read("a", ReadMode::Bypass).unwrap(), a);
        // dirty spill objects cleaned up
        assert!(s.pfs().list(DIRTY_NS).is_empty() || !s.pfs().list(DIRTY_NS).iter().any(|k| k.contains("a#")));
    }

    #[test]
    fn read_range_spans_blocks() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 100);
        let data = rand_data(1000, 9);
        s.write("r", &data, WriteMode::WriteThrough).unwrap();
        for (off, len) in [(0usize, 1000usize), (95, 10), (0, 1), (950, 100), (1000, 4)] {
            let got = s.read_range("r", off as u64, len, ReadMode::TwoLevel).unwrap();
            let end = (off + len).min(1000);
            assert_eq!(got, &data[off.min(1000)..end], "off={off}");
        }
    }

    #[test]
    fn reopen_recovers_persisted_objects() {
        let dir = TempDir::new("tls").unwrap();
        let data = rand_data(700, 10);
        {
            let s = store(&dir, 4096, 256);
            s.write("keep", &data, WriteMode::WriteThrough).unwrap();
        }
        let s = store(&dir, 4096, 256);
        assert!(s.exists("keep"));
        // memory tier is cold: first read comes from the PFS
        assert_eq!(s.read("keep", ReadMode::TwoLevel).unwrap(), data);
        assert!(s.stats().pfs_bytes_read >= 700);
        // second read is hot
        assert_eq!(s.read("keep", ReadMode::TwoLevel).unwrap(), data);
        assert!(s.stats().mem_bytes_read >= 700);
    }

    #[test]
    fn reopen_with_other_block_size_rejected() {
        let dir = TempDir::new("tls").unwrap();
        {
            let _ = store(&dir, 4096, 256);
        }
        let cfg = TlsConfig::builder(dir.path())
            .mem_capacity(4096)
            .block_size(128)
            .build()
            .unwrap();
        assert!(matches!(TwoLevelStore::open(cfg), Err(Error::Config(_))));
    }

    #[test]
    fn reserved_keys_rejected() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        assert!(s.write(".dirty/evil", b"x", WriteMode::Bypass).is_err());
    }

    #[test]
    fn delete_cleans_all_tiers() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("d", &rand_data(500, 11), WriteMode::WriteThrough).unwrap();
        ObjectStore::delete(&s, "d").unwrap();
        assert!(!s.exists("d"));
        assert!(matches!(s.read("d", ReadMode::TwoLevel), Err(Error::NotFound(_))));
        assert!(!s.mem().contains("d#0"));
        // idempotent
        ObjectStore::delete(&s, "d").unwrap();
    }

    #[test]
    fn object_store_trait_defaults() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(300, 12);
        ObjectStore::write(&s, "t", &data).unwrap();
        assert_eq!(ObjectStore::read(&s, "t").unwrap(), data);
        assert_eq!(ObjectStore::size(&s, "t").unwrap(), 300);
        assert_eq!(s.list("t"), vec!["t"]);
        assert_eq!(s.kind(), "tls");
    }

    #[test]
    fn empty_object() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("e", b"", WriteMode::WriteThrough).unwrap();
        assert_eq!(s.read("e", ReadMode::TwoLevel).unwrap(), Vec::<u8>::new());
        assert_eq!(s.read("e", ReadMode::MemOnly).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn large_object_exceeding_memory_two_level_reads() {
        let dir = TempDir::new("tls").unwrap();
        // 1 KiB memory, 4 KiB object: mode (f) with capacity slope (Fig 6)
        let s = store(&dir, 1024, 256);
        let data = rand_data(4096, 13);
        s.write("big", &data, WriteMode::WriteThrough).unwrap();
        assert_eq!(s.read("big", ReadMode::TwoLevel).unwrap(), data);
        let st = s.stats();
        assert!(st.pfs_bytes_read > 0, "must have spilled to PFS");
        assert!(s.mem().used() <= 1024);
    }
}
