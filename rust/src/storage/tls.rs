//! The two-level storage system — the paper's contribution (§3).
//!
//! Composition: an in-memory block tier ([`MemStore`], the paper's
//! Tachyon) over a striped parallel-FS tier ([`Pfs`], the paper's
//! OrangeFS), glued by:
//!
//! - the three **write modes** and three **read modes** of Figure 4
//!   ([`WriteMode`], [`ReadMode`]),
//! - the **block ↔ stripe layout mapping** of Figure 3 (objects live in
//!   the memory tier as `block_size` logical blocks and on the PFS as a
//!   striped checkpoint file),
//! - the dual **I/O buffers** of §3.2 (`app_buffer` between application
//!   and memory tier, `pfs_buffer` between the tiers) — write-through
//!   drives both legs **concurrently** (`concurrent_writethrough`), one
//!   scoped thread feeding the lock-striped memory tier
//!   (`mem_shards`, see [`MemStore::with_shards`]) while the caller
//!   drives the striped PFS write, which fans out one task per server,
//! - the **priority-based read policy** of §3.2: every block read goes to
//!   the nearest tier that has it (memory first, then PFS), and two-level
//!   reads cache what they fetched, subject to LRU/LFU eviction.
//!
//! Mode-(a) writes leave *dirty* blocks that exist only in memory; if
//! eviction pushes a dirty block out, it is checkpointed to a per-block
//! PFS object first (the safety net standing in for Tachyon's lineage),
//! and [`TwoLevelStore::checkpoint`] consolidates an object into its
//! striped PFS file (what the paper's synchronous mode (c) does inline).
//!
//! The v2 streaming surface carries the paper's modes **per handle**:
//! [`TwoLevelStore::create_with`] returns a writer whose chunked appends
//! drive the §3.2 legs as data arrives (write-through: every chunk streams
//! to the striped PFS temp files while blocks stage in the memory tier),
//! and [`TwoLevelStore::open_with`] returns a reader that faults missing
//! blocks from the PFS on demand instead of caching whole objects. Commit
//! is the atomic visibility point in both tiers.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::storage::block::{BlockGeometry, BlockId};
use crate::storage::buffer::{BufferPool, PooledBuf};
use crate::storage::memstore::{MemStats, MemStore};
use crate::storage::pfs::{Pfs, PfsStats};
use crate::storage::{
    read_full_at, ObjectMeta, ObjectReader, ObjectStore, ObjectWriter, ReadMode, Recover,
    RecoveryReport, WriteMode,
};
use crate::util::pool::ThreadPool;

/// Namespace prefix for dirty-block spill objects on the PFS. Registered
/// in [`crate::storage::layout::RESERVED_PREFIXES`].
pub(crate) const DIRTY_NS: &str = ".dirty/";
/// Namespace prefix for memory-tier blocks staged by in-flight writers
/// (invisible to readers until the writer's commit moves them under the
/// real key). Registered in
/// [`crate::storage::layout::RESERVED_PREFIXES`].
pub(crate) const WIP_NS: &str = ".wip/";
/// Marker file pinning the block size of a store root.
const GEOMETRY_MARKER: &str = ".tls-geometry";

/// Uniquifies in-flight writer staging namespaces.
static TLS_WRITER_SEQ: AtomicU64 = AtomicU64::new(0);

/// PFS spill-object name for block `index` of `object`.
fn dirty_key(object: &str, index: u64) -> String {
    format!("{DIRTY_NS}{object}#{index}")
}

/// The parallel-FS tier a [`TwoLevelStore`] checkpoints into: any
/// [`ObjectStore`] that can additionally run its own crash recovery and
/// quarantine objects it must never serve again. [`Pfs`] is the
/// in-process implementation the single-node engine uses; the cluster
/// plane's [`RemotePfs`](crate::cluster::RemotePfs) client implements it
/// over the wire, which is what gives every cluster worker the paper's
/// memory tier on top of the shared striped servers.
pub trait PfsTier: ObjectStore {
    /// Run the tier's own crash recovery: reap writer temps and orphans,
    /// quarantine inconsistent objects, and report what was done.
    fn recover_tier(&self) -> Result<RecoveryReport>;

    /// Park `key` in the tier's quarantine namespace so it reads
    /// `NotFound` under its original name and is never resurrected.
    fn quarantine_object(&self, key: &str) -> Result<()>;
}

impl PfsTier for Pfs {
    fn recover_tier(&self) -> Result<RecoveryReport> {
        self.recover_pfs()
    }

    fn quarantine_object(&self, key: &str) -> Result<()> {
        self.quarantine(key)
    }
}

/// Configuration for [`TwoLevelStore`].
#[derive(Debug, Clone)]
pub struct TlsConfig {
    /// Directory holding both tiers (`mem` marker + `pfs/` subtree).
    pub root: PathBuf,
    /// Byte capacity of the memory tier.
    pub mem_capacity: u64,
    /// Logical block size objects are chunked into.
    pub block_size: u64,
    /// Server directories (stripe targets) of the PFS tier.
    pub pfs_servers: usize,
    /// Stripe unit of the PFS tier.
    pub stripe_size: u64,
    /// Application-side staging buffer of the §3.2 pair.
    pub app_buffer: u64,
    /// PFS-side flush buffer of the §3.2 pair.
    pub pfs_buffer: u64,
    /// Eviction policy of the memory tier: `lru` or `lfu`.
    pub eviction: String,
    /// Worker threads of the shared PFS pool.
    pub workers: usize,
    /// Lock stripes of the memory tier (see
    /// [`MemStore::with_shards`]); `1` reproduces the single-mutex
    /// baseline the fig1 bench compares against.
    pub mem_shards: usize,
    /// Issue the memory-tier and PFS legs of a
    /// [`WriteMode::WriteThrough`] concurrently through the two §3.2
    /// buffers (`false` reproduces the sequential baseline).
    pub concurrent_writethrough: bool,
    /// Coalesce streaming-writer appends until at least this many bytes
    /// are buffered, then push them through both tiers in one batch
    /// (`0` = append-through, the historical behavior).
    pub append_coalesce: usize,
}

impl TlsConfig {
    /// Builder with the paper's §3.2 buffer defaults.
    pub fn builder(root: impl Into<PathBuf>) -> TlsConfigBuilder {
        TlsConfigBuilder {
            cfg: TlsConfig {
                root: root.into(),
                mem_capacity: 256 << 20,
                block_size: 4 << 20,
                pfs_servers: 4,
                stripe_size: 1 << 20,
                app_buffer: 1 << 20,
                pfs_buffer: 4 << 20,
                eviction: "lru".into(),
                workers: 4,
                mem_shards: crate::config::presets::tuning::default_mem_shards(),
                concurrent_writethrough: true,
                append_coalesce: 0,
            },
        }
    }

    /// Derive from an [`crate::config::EngineConfig`].
    pub fn from_engine(e: &crate::config::EngineConfig) -> Self {
        Self {
            root: e.root.clone(),
            mem_capacity: e.mem_capacity,
            block_size: e.block_size,
            pfs_servers: e.pfs_servers,
            stripe_size: e.stripe_size,
            app_buffer: e.app_buffer,
            pfs_buffer: e.pfs_buffer,
            eviction: e.eviction.clone(),
            workers: e.workers,
            mem_shards: e.mem_shards,
            concurrent_writethrough: e.concurrent_writethrough,
            append_coalesce: e.append_coalesce as usize,
        }
    }
}

/// Fluent builder for [`TlsConfig`].
pub struct TlsConfigBuilder {
    cfg: TlsConfig,
}

impl TlsConfigBuilder {
    /// Set the memory-tier byte capacity.
    pub fn mem_capacity(mut self, v: u64) -> Self {
        self.cfg.mem_capacity = v;
        self
    }
    /// Set the logical block size.
    pub fn block_size(mut self, v: u64) -> Self {
        self.cfg.block_size = v;
        self
    }
    /// Set the PFS server (stripe-target) count.
    pub fn pfs_servers(mut self, v: usize) -> Self {
        self.cfg.pfs_servers = v;
        self
    }
    /// Set the PFS stripe unit.
    pub fn stripe_size(mut self, v: u64) -> Self {
        self.cfg.stripe_size = v;
        self
    }
    /// Set the application-side buffer size.
    pub fn app_buffer(mut self, v: u64) -> Self {
        self.cfg.app_buffer = v;
        self
    }
    /// Set the PFS-side buffer size.
    pub fn pfs_buffer(mut self, v: u64) -> Self {
        self.cfg.pfs_buffer = v;
        self
    }
    /// Set the eviction policy (`lru` or `lfu`).
    pub fn eviction(mut self, v: &str) -> Self {
        self.cfg.eviction = v.into();
        self
    }
    /// Set the PFS worker-pool width.
    pub fn workers(mut self, v: usize) -> Self {
        self.cfg.workers = v;
        self
    }
    /// Set the memory-tier lock-stripe count.
    pub fn mem_shards(mut self, v: usize) -> Self {
        self.cfg.mem_shards = v;
        self
    }
    /// Choose dual-leg (true) vs sequential write-through.
    pub fn concurrent_writethrough(mut self, v: bool) -> Self {
        self.cfg.concurrent_writethrough = v;
        self
    }
    /// Set the writer append-coalescing threshold (0 = append-through).
    pub fn append_coalesce(mut self, v: usize) -> Self {
        self.cfg.append_coalesce = v;
        self
    }
    /// Validate the knobs and produce the final config.
    pub fn build(self) -> Result<TlsConfig> {
        let c = &self.cfg;
        if c.block_size == 0 || c.stripe_size == 0 || c.app_buffer == 0 || c.pfs_buffer == 0 {
            return Err(Error::Config("sizes must be > 0".into()));
        }
        if c.pfs_servers == 0 {
            return Err(Error::Config("pfs_servers must be > 0".into()));
        }
        if c.mem_shards == 0 {
            return Err(Error::Config("mem_shards must be > 0".into()));
        }
        Ok(self.cfg)
    }
}

#[derive(Debug, Clone)]
struct ObjEntry {
    size: u64,
    /// Whole-object striped checkpoint exists on the PFS.
    persisted: bool,
}

/// Tier-level counters for the Figure-6 / ablation measurements.
#[derive(Debug, Clone, Copy, Default)]
pub struct TlsStats {
    /// Bytes served from the memory tier.
    pub mem_bytes_read: u64,
    /// Bytes served from the PFS tier.
    pub pfs_bytes_read: u64,
    /// Busy time spent fetching blocks from the memory tier, in
    /// nanoseconds (block-fault granularity; slicing already-fetched
    /// bytes is not counted).
    pub mem_read_nanos: u64,
    /// Busy time spent fetching from the PFS tier, in nanoseconds.
    pub pfs_read_nanos: u64,
    /// Dirty blocks spilled by eviction pressure.
    pub dirty_spills: u64,
    /// Whole-object checkpoints written.
    pub checkpoints: u64,
}

impl TlsStats {
    /// Measured fraction of reads served by the memory tier — the paper's
    /// `f` parameter, observed.
    pub fn f_ratio(&self) -> f64 {
        let total = self.mem_bytes_read + self.pfs_bytes_read;
        if total == 0 {
            0.0
        } else {
            self.mem_bytes_read as f64 / total as f64
        }
    }
}

/// The two-level store, generic over its PFS tier. The default tier is
/// the in-process [`Pfs`] ([`TwoLevelStore::open`]); cluster workers
/// instantiate it over the striped
/// [`RemotePfs`](crate::cluster::RemotePfs) client via
/// [`TwoLevelStore::with_tier`], putting the paper's memory tier in
/// every worker process on top of the shared stripe servers.
pub struct TwoLevelStore<P: PfsTier = Pfs> {
    cfg: TlsConfig,
    mem: MemStore,
    pfs: P,
    objects: Mutex<HashMap<String, ObjEntry>>,
    dirty: Mutex<HashSet<String>>, // storage_key of dirty blocks
    /// Recycled `block_size` accumulators for streaming writers (the §3.2
    /// app-side buffer, at block granularity): steady-state appends
    /// allocate nothing.
    block_pool: BufferPool,
    mem_bytes_read: AtomicU64,
    pfs_bytes_read: AtomicU64,
    mem_read_nanos: AtomicU64,
    pfs_read_nanos: AtomicU64,
    dirty_spills: AtomicU64,
    checkpoints: AtomicU64,
}

impl TwoLevelStore {
    /// Open (or create) a store over an in-process [`Pfs`] tier.
    /// Re-opening a root recovers persisted objects from the PFS tier;
    /// the memory tier starts cold, exactly like a Tachyon restart over
    /// OrangeFS.
    pub fn open(cfg: TlsConfig) -> Result<Self> {
        let pool = Arc::new(ThreadPool::new(cfg.workers.max(2)));
        let pfs = Pfs::open_with_pool(
            &cfg.root.join("pfs"),
            cfg.pfs_servers,
            cfg.stripe_size,
            pool,
        )?;
        Self::check_geometry_marker(&cfg)?;
        Self::with_tier(cfg, pfs)
    }

    /// PFS-tier counters (stripe reads/writes, bytes). Specific to the
    /// in-process [`Pfs`] tier; remote tiers report through the cluster
    /// plane instead.
    pub fn pfs_stats(&self) -> PfsStats {
        self.pfs.stats()
    }

    fn check_geometry_marker(cfg: &TlsConfig) -> Result<()> {
        let marker = cfg.root.join(GEOMETRY_MARKER);
        match std::fs::read_to_string(&marker) {
            Ok(text) => {
                let stored: u64 = text
                    .trim()
                    .parse()
                    .map_err(|_| Error::Config("corrupt geometry marker".into()))?;
                if stored != cfg.block_size {
                    return Err(Error::Config(format!(
                        "store was created with block_size {stored}, reopened with {}",
                        cfg.block_size
                    )));
                }
                Ok(())
            }
            Err(_) => {
                std::fs::create_dir_all(&cfg.root).map_err(|e| Error::io(&cfg.root, e))?;
                std::fs::write(&marker, cfg.block_size.to_string())
                    .map_err(|e| Error::io(&marker, e))?;
                Ok(())
            }
        }
    }
}

impl<P: PfsTier> TwoLevelStore<P> {
    /// Build a store over an already-constructed PFS tier — how a
    /// cluster worker layers its memory tier over the shared
    /// [`RemotePfs`](crate::cluster::RemotePfs) client. The tier's
    /// root/geometry bookkeeping (directories, the block-size marker)
    /// is the caller's concern; everything else matches
    /// [`TwoLevelStore::open`].
    pub fn with_tier(cfg: TlsConfig, tier: P) -> Result<Self> {
        if cfg.block_size == 0 {
            return Err(Error::Config("block_size must be > 0".into()));
        }
        let mem = MemStore::with_shards(cfg.mem_capacity, &cfg.eviction, cfg.mem_shards)?;

        // Recover the object table from PFS contents. Only consolidated
        // checkpoints resurrect an entry: mode-(a) data is volatile until
        // checkpointed (exactly Tachyon's restart semantics), so `.dirty/`
        // spill blocks of a previous incarnation never rebuild an object —
        // a partial spill set would serve a prefix, and even a complete one
        // belongs to a write whose commit this process cannot vouch for.
        // [`TwoLevelStore::recover`] quarantines those spills; quarantined
        // objects stay invisible too.
        let mut objects = HashMap::new();
        for key in tier.list("") {
            if key.starts_with(DIRTY_NS) || key.starts_with(crate::storage::pfs::QUARANTINE_NS) {
                continue;
            }
            let size = tier.size(&key)?;
            objects.insert(
                key,
                ObjEntry {
                    size,
                    persisted: true,
                },
            );
        }

        let block_pool = BufferPool::new(cfg.block_size as usize, 4);
        Ok(Self {
            cfg,
            mem,
            pfs: tier,
            objects: Mutex::new(objects),
            dirty: Mutex::new(HashSet::new()),
            block_pool,
            mem_bytes_read: AtomicU64::new(0),
            pfs_bytes_read: AtomicU64::new(0),
            mem_read_nanos: AtomicU64::new(0),
            pfs_read_nanos: AtomicU64::new(0),
            dirty_spills: AtomicU64::new(0),
            checkpoints: AtomicU64::new(0),
        })
    }

    /// The validated configuration this store was built with.
    pub fn config(&self) -> &TlsConfig {
        &self.cfg
    }

    /// Memory-tier counters (hits, evictions, used bytes).
    pub fn mem_stats(&self) -> MemStats {
        self.mem.stats()
    }

    /// Combined two-tier counters for the metrics plane.
    pub fn stats(&self) -> TlsStats {
        TlsStats {
            mem_bytes_read: self.mem_bytes_read.load(Ordering::Relaxed),
            pfs_bytes_read: self.pfs_bytes_read.load(Ordering::Relaxed),
            mem_read_nanos: self.mem_read_nanos.load(Ordering::Relaxed),
            pfs_read_nanos: self.pfs_read_nanos.load(Ordering::Relaxed),
            dirty_spills: self.dirty_spills.load(Ordering::Relaxed),
            checkpoints: self.checkpoints.load(Ordering::Relaxed),
        }
    }

    /// Direct access to the PFS tier (the coordinator and benches use it).
    pub fn pfs(&self) -> &P {
        &self.pfs
    }

    /// Direct access to the memory tier.
    pub fn mem(&self) -> &MemStore {
        &self.mem
    }

    fn geometry(&self, size: u64) -> BlockGeometry {
        // lint:allow(no-panic): `cfg.block_size` was validated non-zero by
        // `with_tier`, which every constructor routes through
        BlockGeometry::new(size, self.cfg.block_size).expect("validated block size")
    }

    /// Handle eviction victims: dirty blocks must hit the PFS before the
    /// bytes disappear (the safety net standing in for Tachyon lineage).
    fn spill_evicted(&self, evicted: Vec<(String, Arc<[u8]>)>) -> Result<()> {
        if evicted.is_empty() {
            return Ok(());
        }
        let mut dirty = self.dirty.lock().unwrap();
        for (key, bytes) in evicted {
            if dirty.remove(&key) {
                // a malformed storage key means the dirty bytes cannot be
                // routed to a spill file — surface it instead of dropping
                // the only copy on the floor (or panicking mid-eviction)
                let parsed = key
                    .rsplit_once('#')
                    .and_then(|(obj, idx)| Some((obj, idx.parse::<u64>().ok()?)));
                let Some((obj, idx)) = parsed else {
                    return Err(Error::RecoveryNeeded(format!(
                        "dirty block `{key}`: malformed storage key, cannot spill"
                    )));
                };
                self.pfs.write(&dirty_key(obj, idx), &bytes)?;
                self.dirty_spills.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Overwrite hygiene: purge resident blocks of `key` in `[from, to)`
    /// together with their dirty flags and `.dirty/` spill objects, so a
    /// replaced version can neither serve stale bytes under the new
    /// geometry nor leak spill files.
    fn purge_stale_blocks(&self, key: &str, from: u64, to: u64) {
        if from >= to {
            return;
        }
        // drop the flags under the lock, do the per-block I/O outside it
        // so concurrent commits/evictions never wait on filesystem unlinks
        {
            let mut dirty = self.dirty.lock().unwrap();
            for i in from..to {
                dirty.remove(&BlockId::new(key, i).storage_key());
            }
        }
        for i in from..to {
            self.mem.remove(&BlockId::new(key, i).storage_key());
            // delete is idempotent for missing spills; an Err is a real
            // filesystem failure and the orphan is recover()'s problem
            if let Err(e) = self.pfs.delete(&dirty_key(key, i)) {
                crate::log_warn!("purge of stale spill `{key}#{i}` failed: {e}");
            }
        }
    }

    /// As [`TwoLevelStore::purge_stale_blocks`] but keeps the resident
    /// blocks — used after a write-through commit installed fresh blocks
    /// under the same indices and only the *old* version's dirty flags and
    /// spill files must go.
    fn purge_stale_dirty(&self, key: &str, upto: u64) {
        {
            let mut dirty = self.dirty.lock().unwrap();
            for i in 0..upto {
                dirty.remove(&BlockId::new(key, i).storage_key());
            }
        }
        for i in 0..upto {
            // same contract as purge_stale_blocks: only real filesystem
            // failures land here, and recover() reaps what this pass missed
            if let Err(e) = self.pfs.delete(&dirty_key(key, i)) {
                crate::log_warn!("purge of stale spill `{key}#{i}` failed: {e}");
            }
        }
    }

    /// Insert blocks into the memory tier, spilling dirty victims.
    fn put_blocks(&self, object: &str, data: &[u8], mark_dirty: bool) -> Result<()> {
        let geo = self.geometry(data.len() as u64);
        for i in 0..geo.num_blocks() {
            let (s, e) = geo.block_range(i);
            let bytes: Arc<[u8]> = data[s as usize..e as usize].to_vec().into();
            let key = BlockId::new(object, i).storage_key();
            if mark_dirty {
                self.dirty.lock().unwrap().insert(key.clone());
            }
            let evicted = self.mem.put(&key, bytes)?;
            self.spill_evicted(evicted)?;
        }
        Ok(())
    }

    /// Whether `key` is a dot-prefixed key callers may not write:
    /// everything under `.` is reserved for store internals (the
    /// registered [`crate::storage::layout::RESERVED_PREFIXES`]
    /// namespaces plus the geometry marker) **except** the
    /// [`SHUFFLE_NS`](crate::storage::SHUFFLE_NS) shuffle namespace,
    /// which the compute plane deliberately routes through the store so
    /// intermediate job data rides the two-level tiers (and recovery can
    /// reap it).
    fn is_reserved_key(key: &str) -> bool {
        key.starts_with('.') && !key.starts_with(crate::storage::SHUFFLE_NS)
    }

    /// Write under an explicit mode (Figure 4 a–c).
    pub fn write(&self, key: &str, data: &[u8], mode: WriteMode) -> Result<()> {
        if Self::is_reserved_key(key) {
            return Err(Error::InvalidArg(
                "keys starting with '.' are reserved".into(),
            ));
        }
        // block count of any previous version (overwrite hygiene below)
        let old_blocks = self
            .objects
            .lock()
            .unwrap()
            .get(key)
            .map(|o| self.geometry(o.size).num_blocks());
        match mode {
            WriteMode::MemOnly => {
                // a block bigger than the memory tier can never be MemOnly
                if self.cfg.block_size.min(data.len() as u64) > self.cfg.mem_capacity {
                    return Err(Error::OverCapacity {
                        need: data.len() as u64,
                        capacity: self.cfg.mem_capacity,
                    });
                }
                self.put_blocks(key, data, true)?;
                if let Some(oldn) = old_blocks {
                    // shrinking overwrite: drop the old version's blocks
                    // beyond the new geometry (resident + dirty + spills)
                    let newn = self.geometry(data.len() as u64).num_blocks();
                    self.purge_stale_blocks(key, newn, oldn);
                }
                self.objects.lock().unwrap().insert(
                    key.to_string(),
                    ObjEntry {
                        size: data.len() as u64,
                        persisted: false,
                    },
                );
            }
            WriteMode::Bypass => {
                self.pfs.write(key, data)?;
                if let Some(oldn) = old_blocks {
                    // Bypass caches nothing, so every cached block of the
                    // replaced version is stale — purge them all, or later
                    // TwoLevel reads would serve old bytes under the new
                    // geometry
                    let newn = self.geometry(data.len() as u64).num_blocks();
                    self.purge_stale_blocks(key, 0, newn.max(oldn));
                }
                self.objects.lock().unwrap().insert(
                    key.to_string(),
                    ObjEntry {
                        size: data.len() as u64,
                        persisted: true,
                    },
                );
            }
            WriteMode::WriteThrough => {
                // §4, eq. (6): synchronous write to both tiers; throughput
                // bounded by the PFS (the slower leg). The two legs ride
                // the two §3.2 buffers independently, so they are issued
                // concurrently: one scoped thread feeds the memory tier
                // while this thread drives the striped PFS write (which
                // itself fans out per server). Per-block over-capacity is
                // pre-checked so the failure mode matches the sequential
                // path (no PFS write happens when the mem leg cannot fit
                // a single block).
                if !data.is_empty()
                    && self.cfg.block_size.min(data.len() as u64) > self.cfg.mem_capacity
                {
                    return Err(Error::OverCapacity {
                        need: data.len() as u64,
                        capacity: self.cfg.mem_capacity,
                    });
                }
                // `pfs_ran` distinguishes "PFS leg executed" (always, in
                // the concurrent fork) from the sequential path, which
                // never starts it after a mem-leg failure.
                let (mem_res, pfs_res, pfs_ran) = if self.cfg.concurrent_writethrough {
                    let (m, p) = std::thread::scope(|s| {
                        let mem_leg = s.spawn(|| self.put_blocks(key, data, false));
                        let pfs_res = self.pfs.write(key, data);
                        // a panicked leg fails the write instead of tearing
                        // down the calling thread
                        let mem_res = mem_leg.join().unwrap_or_else(|_| {
                            Err(Error::Job("memory-tier write leg panicked".into()))
                        });
                        (mem_res, pfs_res)
                    });
                    (m, p, true)
                } else {
                    match self.put_blocks(key, data, false) {
                        Err(e) => (Err(e), Ok(()), false),
                        Ok(()) => (Ok(()), self.pfs.write(key, data), true),
                    }
                };
                if pfs_ran && pfs_res.is_err() {
                    // The PFS leg failed: roll this key's freshly cached
                    // blocks out of the memory tier so a write that
                    // returned Err is never served from cache (readers
                    // fall back to whatever the PFS holds).
                    let geo = self.geometry(data.len() as u64);
                    for i in 0..geo.num_blocks() {
                        self.mem.remove(&BlockId::new(key, i).storage_key());
                    }
                } else if pfs_ran && mem_res.is_err() {
                    // PFS leg landed, mem leg failed. For a fresh key,
                    // remove the orphan so a restart's PFS recovery cannot
                    // resurrect a write that returned Err — matching the
                    // sequential path. For a previously persisted key the
                    // old bytes are already overwritten in place and
                    // cannot be restored; commit the fully landed new
                    // object so reads stay self-consistent instead of
                    // mixing the stale size with the new PFS contents.
                    let old_entry = self.objects.lock().unwrap().get(key).cloned();
                    match old_entry {
                        Some(old) if old.persisted => {
                            // Purge every cached block of either version
                            // first: the failed mem leg may have stopped
                            // partway, leaving stale old-version blocks
                            // that the new geometry would happily serve.
                            let max_size = old.size.max(data.len() as u64);
                            let geo = self.geometry(max_size);
                            for i in 0..geo.num_blocks() {
                                self.mem.remove(&BlockId::new(key, i).storage_key());
                            }
                            self.objects.lock().unwrap().insert(
                                key.to_string(),
                                ObjEntry {
                                    size: data.len() as u64,
                                    persisted: true,
                                },
                            );
                        }
                        _ => {
                            // The rollback itself is load-bearing: a
                            // fresh-key orphan left on the PFS would be
                            // resurrected by restart recovery even though
                            // this write returns Err. If the cleanup
                            // fails, say so distinctly — recover() owns
                            // the leftover from here.
                            if let Err(cleanup) = self.pfs.delete(key) {
                                let mem_err = mem_res
                                    .as_ref()
                                    .err()
                                    .map(ToString::to_string)
                                    .unwrap_or_default();
                                return Err(Error::RecoveryNeeded(format!(
                                    "write-through of fresh key `{key}`: mem leg failed \
                                     ({mem_err}) and the PFS rollback also failed \
                                     ({cleanup}); run recover() before trusting a restart"
                                )));
                            }
                        }
                    }
                }
                mem_res?;
                pfs_res?;
                self.objects.lock().unwrap().insert(
                    key.to_string(),
                    ObjEntry {
                        size: data.len() as u64,
                        persisted: true,
                    },
                );
            }
        }
        Ok(())
    }

    fn entry(&self, key: &str) -> Result<ObjEntry> {
        if let Some(e) = self.objects.lock().unwrap().get(key).cloned() {
            return Ok(e);
        }
        // Cross-process visibility: cluster peers commit objects to the
        // shared PFS tier behind this table's back. Adopt a tier-resident
        // key as an already-persisted entry (objects are write-once, so
        // the size read here cannot go stale).
        if !Self::is_reserved_key(key) && self.pfs.exists(key) {
            let size = self.pfs.size(key)?;
            let e = ObjEntry {
                size,
                persisted: true,
            };
            self.objects
                .lock()
                .unwrap()
                .entry(key.to_string())
                .or_insert_with(|| e.clone());
            return Ok(e);
        }
        Err(Error::NotFound(key.to_string()))
    }

    /// Fetch one block through the priority policy. Returns the bytes and
    /// which tier served them.
    ///
    /// Concurrency: a dirty block evicted by another thread is briefly in
    /// flight between leaving the memory tier and landing in the PFS dirty
    /// namespace (eviction and spill are not one atomic step). The block
    /// is never *lost* — it is in memory, in `.dirty/`, or the object has
    /// just been checkpointed — so a miss on every tier retries with a
    /// fresh object-table snapshot until the in-flight write lands.
    fn read_block(&self, key: &str, index: u64, cache: bool) -> Result<(Arc<[u8]>, bool)> {
        let skey = BlockId::new(key, index).storage_key();
        const MAX_ATTEMPTS: u32 = 500;
        for attempt in 0..MAX_ATTEMPTS {
            let t0 = Instant::now();
            if let Some(bytes) = self.mem.get(&skey) {
                self.mem_read_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                return Ok((bytes, true));
            }
            // miss → PFS: prefer the consolidated checkpoint, else spill
            let entry = self.entry(key)?;
            let geo = self.geometry(entry.size);
            if index >= geo.num_blocks() {
                // a shrink-overwrite landed since the caller snapshotted
                // its geometry: the block no longer exists in the live
                // version, and never will — don't take the in-flight
                // retry path (and don't let block_range underflow below)
                return Err(Error::NotFound(format!(
                    "{key} block {index}: beyond the current object ({} blocks)",
                    geo.num_blocks()
                )));
            }
            let (s, e) = geo.block_range(index);
            let t0 = Instant::now();
            let fetched: Result<Vec<u8>> = if entry.persisted {
                // chunked transfer through the §3.2 pfs buffer, straight
                // into the block buffer (the reader handle fans each
                // chunk's stripe reads out per server; no per-chunk
                // temporaries)
                (|| -> Result<Vec<u8>> {
                    let r = self.pfs.open(key)?;
                    let mut out = vec![0u8; (e - s) as usize];
                    let mut off = 0usize;
                    let chunk = self.cfg.pfs_buffer.max(1) as usize;
                    while off < out.len() {
                        let take = (out.len() - off).min(chunk);
                        read_full_at(r.as_ref(), s + off as u64, &mut out[off..off + take])?;
                        off += take;
                    }
                    Ok(out)
                })()
            } else {
                self.pfs.read(&dirty_key(key, index))
            };
            match fetched {
                Ok(bytes) => {
                    self.pfs_read_nanos
                        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    let bytes: Arc<[u8]> = bytes.into();
                    if cache {
                        let evicted = self.mem.put(&skey, Arc::clone(&bytes))?;
                        self.spill_evicted(evicted)?;
                    }
                    return Ok((bytes, false));
                }
                // in-flight spill/checkpoint: back off and re-snapshot
                Err(Error::NotFound(_)) if attempt + 1 < MAX_ATTEMPTS => {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                Err(e) => return Err(e),
            }
        }
        Err(Error::NotFound(format!("{key} block {index}: lost")))
    }

    /// Read under an explicit mode (Figure 4 d–f).
    pub fn read(&self, key: &str, mode: ReadMode) -> Result<Vec<u8>> {
        let entry = self.entry(key)?;
        match mode {
            ReadMode::Bypass => {
                if !entry.persisted {
                    return Err(Error::NotFound(format!(
                        "{key}: not persisted; Bypass reads only the PFS tier"
                    )));
                }
                let t0 = Instant::now();
                let data = self.pfs.read(key)?;
                self.pfs_read_nanos
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                self.pfs_bytes_read
                    .fetch_add(data.len() as u64, Ordering::Relaxed);
                Ok(data)
            }
            ReadMode::MemOnly | ReadMode::TwoLevel => {
                let geo = self.geometry(entry.size);
                let mut out = Vec::with_capacity(entry.size as usize);
                for i in 0..geo.num_blocks() {
                    let skey = BlockId::new(key, i).storage_key();
                    let (bytes, from_mem) = match mode {
                        ReadMode::MemOnly => match self.mem.get(&skey) {
                            Some(b) => (b, true),
                            None => {
                                return Err(Error::NotFound(format!(
                                    "{key} block {i}: evicted from memory tier (MemOnly read)"
                                )))
                            }
                        },
                        _ => self.read_block(key, i, true)?,
                    };
                    if from_mem {
                        self.mem_bytes_read
                            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    } else {
                        self.pfs_bytes_read
                            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    }
                    out.extend_from_slice(&bytes);
                }
                Ok(out)
            }
        }
    }

    /// Ranged read under a mode.
    pub fn read_range(&self, key: &str, offset: u64, len: usize, mode: ReadMode) -> Result<Vec<u8>> {
        let entry = self.entry(key)?;
        if matches!(mode, ReadMode::Bypass) {
            if !entry.persisted {
                return Err(Error::NotFound(format!("{key}: not persisted")));
            }
            let t0 = Instant::now();
            let data = self.pfs.read_range(key, offset, len)?;
            self.pfs_read_nanos
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            self.pfs_bytes_read
                .fetch_add(data.len() as u64, Ordering::Relaxed);
            return Ok(data);
        }
        let geo = self.geometry(entry.size);
        let pieces = geo.blocks_for_range(offset, len as u64);
        let mut out = Vec::new();
        for (i, s, e) in pieces {
            let (bytes, from_mem) = match mode {
                ReadMode::MemOnly => {
                    let skey = BlockId::new(key, i).storage_key();
                    match self.mem.get(&skey) {
                        Some(b) => (b, true),
                        None => {
                            return Err(Error::NotFound(format!(
                                "{key} block {i}: not in memory tier"
                            )))
                        }
                    }
                }
                _ => self.read_block(key, i, true)?,
            };
            let served = (e - s) as u64;
            if from_mem {
                self.mem_bytes_read.fetch_add(served, Ordering::Relaxed);
            } else {
                self.pfs_bytes_read.fetch_add(served, Ordering::Relaxed);
            }
            out.extend_from_slice(&bytes[s as usize..e as usize]);
        }
        Ok(out)
    }

    /// Consolidate `key` into its striped whole-object checkpoint on the
    /// PFS (no-op if already persisted). This is what the coordinator's
    /// checkpointer calls for mode-(a) data.
    ///
    /// The checkpoint *streams*: each block flows straight from the memory
    /// tier (or its dirty spill) into the tier's chunked streaming
    /// writer, so the store never materializes the whole object, and a
    /// crash mid-checkpoint leaves only invisible staged temps (the
    /// writer's commit is the atomic visibility point). Blocks read for
    /// checkpointing are *not* cached back, so a background checkpoint
    /// cannot evict the working set.
    pub fn checkpoint(&self, key: &str) -> Result<()> {
        let entry = self.entry(key)?;
        if entry.persisted {
            return Ok(());
        }
        let geo = self.geometry(entry.size);
        let mut w = self.pfs.create(key)?;
        for i in 0..geo.num_blocks() {
            let (bytes, from_mem) = self.read_block(key, i, false)?;
            if from_mem {
                self.mem_bytes_read
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
            } else {
                self.pfs_bytes_read
                    .fetch_add(bytes.len() as u64, Ordering::Relaxed);
            }
            w.append(&bytes)?;
        }
        w.commit()?;
        // Flip the object to persisted *before* dropping the spill blocks:
        // concurrent readers that miss memory then re-snapshot the entry
        // and route to the consolidated checkpoint instead of the (soon to
        // vanish) dirty namespace.
        self.objects.lock().unwrap().insert(
            key.to_string(),
            ObjEntry {
                size: entry.size,
                persisted: true,
            },
        );
        let mut dirty = self.dirty.lock().unwrap();
        for i in 0..geo.num_blocks() {
            dirty.remove(&BlockId::new(key, i).storage_key());
            // the checkpoint already landed, so a leftover spill is an
            // orphan (correctness-neutral); recover() reaps it later
            if let Err(e) = self.pfs.delete(&dirty_key(key, i)) {
                crate::log_warn!("checkpoint `{key}`: spill cleanup for block {i} failed: {e}");
            }
        }
        drop(dirty);
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Keys of objects not yet persisted (the checkpointer's work queue).
    pub fn unpersisted(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .objects
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| !e.persisted)
            .map(|(k, _)| k.clone())
            .collect();
        v.sort();
        v
    }

    /// Evict an object's blocks from the memory tier (for cache-pressure
    /// experiments); dirty blocks are spilled first via checkpoint.
    pub fn evict_object(&self, key: &str) -> Result<()> {
        let entry = self.entry(key)?;
        if !entry.persisted {
            self.checkpoint(key)?;
        }
        let geo = self.geometry(entry.size);
        for i in 0..geo.num_blocks() {
            self.mem.remove(&BlockId::new(key, i).storage_key());
        }
        Ok(())
    }

    /// Crash recovery for the two-level store; see
    /// [`crate::storage::Recover`] for the contract and
    /// `docs/FAULT_MODEL.md` for the failure taxonomy. This is the
    /// paper's "Tachyon restart over OrangeFS" scenario made explicit:
    /// the memory tier restarts empty, the PFS tier is the durable source
    /// of truth, and everything in between must be repaired or refused.
    ///
    /// 1. The PFS tier recovers itself ([`PfsTier::recover_tier`]): writer
    ///    temp datafiles and torn metadata go, inconsistent objects are
    ///    quarantined, orphan datafiles are removed.
    /// 2. Abandoned `.wip/` staging blocks (a writer whose process died
    ///    mid-stream *in this incarnation*) are dropped from the memory
    ///    tier — they were never visible and never will be.
    /// 3. Object-table entries whose consolidated checkpoint the PFS pass
    ///    quarantined are dropped (cached blocks and dirty flags purged),
    ///    so the key reads `NotFound` instead of failing block faults.
    /// 4. `.dirty/` spill objects are reconciled: spills of a
    ///    *checkpointed* object are stale (the checkpoint supersedes
    ///    them) and dropped; spills of an object this process knows as
    ///    live-but-unpersisted are its backing store and kept; spills of
    ///    an *unknown* object belong to a previous incarnation's
    ///    uncommitted mode-(a) data — they are quarantined, never
    ///    resurrected (a partial spill set would be a prefix).
    /// 5. [`SHUFFLE_NS`](crate::storage::SHUFFLE_NS) shuffle spills are
    ///    reaped across both tiers: a job that died mid-shuffle leaves
    ///    only recomputable intermediate data, which recovery deletes
    ///    outright (never quarantines — see `docs/FAULT_MODEL.md`).
    pub fn recover(&self) -> Result<RecoveryReport> {
        let mut report = self.pfs.recover_tier()?;

        // pass 2: abandoned in-memory write staging
        for key in self.mem.list(WIP_NS) {
            self.mem.remove(&key);
            report.temps_removed += 1;
        }

        // pass 3: drop table entries whose PFS backing was quarantined
        let stale: Vec<(String, u64)> = {
            let objects = self.objects.lock().unwrap();
            objects
                .iter()
                .filter(|(k, e)| e.persisted && !self.pfs.exists(k.as_str()))
                .map(|(k, e)| (k.clone(), e.size))
                .collect()
        };
        for (key, size) in &stale {
            let blocks = self.geometry(*size).num_blocks();
            self.purge_stale_blocks(key, 0, blocks);
            self.objects.lock().unwrap().remove(key);
        }

        // pass 4: reconcile dirty-spill objects
        for skey in self.pfs.list(DIRTY_NS) {
            let owner = skey[DIRTY_NS.len()..]
                .rsplit_once('#')
                .map(|(obj, _)| obj.to_string());
            let entry = owner
                .as_deref()
                .and_then(|obj| self.objects.lock().unwrap().get(obj).cloned());
            match (owner, entry) {
                (Some(_), Some(e)) if e.persisted => {
                    // checkpoint supersedes the spill
                    self.pfs.delete(&skey)?;
                    report.spills_dropped += 1;
                }
                (Some(_), Some(_)) => {
                    // live unpersisted object of *this* process: the spill
                    // is its backing store — keep it
                }
                _ => {
                    // unknown owner (previous incarnation's uncommitted
                    // mode-(a) data) or malformed name: never resurrect
                    self.pfs.quarantine_object(&skey)?;
                    report.quarantined.push(skey);
                }
            }
        }

        // pass 5: reap shuffle residue left in *this* store's table. The
        // PFS pass already deleted (and counted) the on-disk spill
        // objects, and pass 3 dropped their table entries; this catches
        // anything that never reached the PFS (e.g. an in-process recover
        // over a live store holding unpersisted shuffle entries). The
        // shared helper tolerates keys vanishing mid-reap.
        report.shuffle_reaped += crate::storage::reap_shuffle(self)?;
        Ok(report)
    }

    /// Open a streaming reader under an explicit read mode (Figure 4 d–f).
    /// The mode rides the handle, so every `read_at` follows that tier
    /// policy:
    ///
    /// - `MemOnly` (d): blocks must be memory-resident; `NotFound` if one
    ///   was evicted.
    /// - `Bypass` (e): straight off the PFS stripes, no caching; requires
    ///   a persisted object.
    /// - `TwoLevel` (f): memory first; missing blocks are **faulted from
    ///   the PFS on demand, block by block** (each block rides the §3.2
    ///   `pfs_buffer` as stripe reads fanned per server) and cached back —
    ///   a partial scan warms only the blocks it touched, never the whole
    ///   object.
    pub fn open_with(&self, key: &str, mode: ReadMode) -> Result<Box<dyn ObjectReader + '_>> {
        let entry = self.entry(key)?;
        if matches!(mode, ReadMode::Bypass) && !entry.persisted {
            return Err(Error::NotFound(format!(
                "{key}: not persisted; Bypass reads only the PFS tier"
            )));
        }
        let bypass = if matches!(mode, ReadMode::Bypass) {
            // snapshot the PFS geometry once per handle, not per read_at
            Some(self.pfs.open(key)?)
        } else {
            None
        };
        Ok(Box::new(TlsReader {
            store: self,
            key: key.to_string(),
            size: entry.size,
            mode,
            bypass,
        }))
    }

    /// Start a streaming writer under an explicit write mode (Figure 4
    /// a–c). The mode rides the handle:
    ///
    /// - `WriteThrough` (c): both §3.2 legs run **per append** — each
    ///   chunk streams into the striped PFS temp datafiles as it arrives,
    ///   while the memory leg fills recycled `block_size` accumulators
    ///   (the store's [`BufferPool`]) and stages finished blocks in the
    ///   memory tier under a hidden `.wip/` name. With
    ///   `concurrent_writethrough` (the default) the two legs of each
    ///   append run concurrently — the PFS leg on a scoped thread, the
    ///   memory leg on the caller's — exactly like the whole-object
    ///   write-through path. `commit` publishes the PFS object
    ///   atomically, then moves the staged blocks under the real key
    ///   (pure `Arc` moves — no copies). If a block cannot fit the
    ///   memory tier, the writer degrades to PFS-only instead of
    ///   failing: the committed object is simply served from the PFS.
    /// - `MemOnly` (a): blocks buffer in the writer and land (dirty) in
    ///   the memory tier at commit — same over-capacity semantics as the
    ///   whole-object mode-(a) write.
    /// - `Bypass` (b): chunks stream to the PFS only.
    ///
    /// In every mode, readers see the old object (or `NotFound` for a
    /// fresh key) until `commit`; `abort` or dropping the writer
    /// uncommitted leaves no trace in either tier.
    pub fn create_with(&self, key: &str, mode: WriteMode) -> Result<Box<dyn ObjectWriter + '_>> {
        if Self::is_reserved_key(key) {
            return Err(Error::InvalidArg(
                "keys starting with '.' are reserved".into(),
            ));
        }
        let pfs = match mode {
            WriteMode::MemOnly => None,
            _ => Some(self.pfs.create(key)?),
        };
        // Bypass writers never run the memory leg: don't check a block
        // accumulator out of the pool they would only hold hostage
        let block = match mode {
            WriteMode::Bypass => None,
            _ => Some(self.block_pool.take()),
        };
        Ok(Box::new(TlsWriter {
            store: self,
            key: key.to_string(),
            mode,
            wip: format!("{WIP_NS}{}", TLS_WRITER_SEQ.fetch_add(1, Ordering::Relaxed)),
            block,
            staged: 0,
            pending: Vec::new(),
            pfs,
            written: 0,
            mem_ok: true,
            coalesce: self.cfg.append_coalesce,
            carry: Vec::new(),
            finished: false,
        }))
    }
}

/// Streaming reader over a two-level object; see
/// [`TwoLevelStore::open_with`]. `size` and the paper's read mode are
/// snapshotted at open; `read_at` is stateless and shareable across
/// threads (prefetch windows read through one handle concurrently).
pub struct TlsReader<'a, P: PfsTier = Pfs> {
    store: &'a TwoLevelStore<P>,
    key: String,
    size: u64,
    mode: ReadMode,
    /// Bypass mode only: the PFS reader snapshotted at open.
    bypass: Option<Box<dyn ObjectReader + 'a>>,
}

impl<P: PfsTier> ObjectReader for TlsReader<'_, P> {
    fn len(&self) -> u64 {
        self.size
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        if offset >= self.size || buf.is_empty() {
            return Ok(0);
        }
        let take = crate::storage::clamped_len(offset, buf.len(), self.size);
        let buf = &mut buf[..take];
        if let Some(r) = &self.bypass {
            read_full_at(r.as_ref(), offset, buf)?;
            self.store
                .pfs_bytes_read
                .fetch_add(take as u64, Ordering::Relaxed);
            return Ok(take);
        }
        let geo = self.store.geometry(self.size);
        let block_size = self.store.cfg.block_size;
        for (i, s, e) in geo.blocks_for_range(offset, take as u64) {
            let (bytes, from_mem) = match self.mode {
                ReadMode::MemOnly => {
                    let skey = BlockId::new(&self.key, i).storage_key();
                    match self.store.mem.get(&skey) {
                        Some(b) => (b, true),
                        None => {
                            return Err(Error::NotFound(format!(
                                "{} block {i}: not in memory tier (MemOnly read)",
                                self.key
                            )))
                        }
                    }
                }
                _ => self.store.read_block(&self.key, i, true)?,
            };
            let served = (e - s) as usize;
            if from_mem {
                self.store
                    .mem_bytes_read
                    .fetch_add(served as u64, Ordering::Relaxed);
            } else {
                self.store
                    .pfs_bytes_read
                    .fetch_add(served as u64, Ordering::Relaxed);
            }
            let dst = (i * block_size + s - offset) as usize;
            buf[dst..dst + served].copy_from_slice(&bytes[s as usize..e as usize]);
        }
        Ok(take)
    }
}

/// Streaming writer into the two-level store; see
/// [`TwoLevelStore::create_with`] for the per-mode data path and
/// visibility guarantees.
pub struct TlsWriter<'a, P: PfsTier = Pfs> {
    store: &'a TwoLevelStore<P>,
    key: String,
    mode: WriteMode,
    /// Hidden staging object name for in-flight memory-tier blocks.
    wip: String,
    /// Current partial block, recycled through the store's block pool
    /// (`None` for Bypass writers, which have no memory leg).
    block: Option<PooledBuf<'a>>,
    /// Completed blocks staged in the memory tier under `wip` (WriteThrough).
    staged: u64,
    /// Completed blocks buffered until commit (MemOnly).
    pending: Vec<Arc<[u8]>>,
    /// Streaming PFS-tier leg (WriteThrough / Bypass).
    pfs: Option<Box<dyn ObjectWriter + 'a>>,
    written: u64,
    /// Memory leg still caching; WriteThrough flips this off (degrading to
    /// PFS-only) when a block cannot fit the tier.
    mem_ok: bool,
    /// Coalescing threshold snapshotted from [`TlsConfig::append_coalesce`].
    coalesce: usize,
    /// Bytes buffered awaiting the next coalesced flush through both legs
    /// (always empty when `coalesce == 0`).
    carry: Vec<u8>,
    finished: bool,
}

impl<P: PfsTier> TlsWriter<'_, P> {
    fn append_inner(&mut self, chunk: &[u8]) -> Result<()> {
        if chunk.is_empty() {
            return Ok(());
        }
        // below this, forking the legs costs more than the overlap buys
        const PARALLEL_APPEND_MIN: usize = 64 << 10;

        self.written += chunk.len() as u64;
        let mem_leg = !matches!(self.mode, WriteMode::Bypass) && self.mem_ok;
        if mem_leg
            && self.pfs.is_some()
            && self.store.cfg.concurrent_writethrough
            && chunk.len() >= PARALLEL_APPEND_MIN
        {
            // Dual-leg append (the §3.2 buffers, per chunk): the PFS leg
            // runs on a scoped thread while this thread drives the memory
            // leg — the same `concurrent_writethrough` contract as the
            // whole-object write-through path.
            // lint:allow(no-panic): `self.pfs.is_some()` guards this branch
            let mut pfs = self.pfs.take().expect("checked is_some");
            let (pfs, pfs_res, mem_res) = std::thread::scope(|s| {
                let pfs_leg = s.spawn(move || {
                    let r = pfs.append(chunk);
                    (pfs, r)
                });
                let mem_res = self.accumulate(chunk);
                // a panicked PFS leg fails the append (losing the leg
                // writer, which only Drop's best-effort cancel would use)
                match pfs_leg.join() {
                    Ok((pfs, pfs_res)) => (Some(pfs), pfs_res, mem_res),
                    Err(_) => (
                        None,
                        Err(Error::Job("PFS write leg panicked".into())),
                        mem_res,
                    ),
                }
            });
            self.pfs = pfs;
            pfs_res?;
            mem_res
        } else {
            if let Some(w) = &mut self.pfs {
                w.append(chunk)?; // PFS leg streams per append
            }
            if mem_leg {
                self.accumulate(chunk)?;
            }
            Ok(())
        }
    }

    /// Memory leg of one append: fill `block_size` accumulators from
    /// `chunk`, sealing each full one. Stops early if the leg degrades
    /// (`mem_ok` flips off); the PFS leg is unaffected.
    fn accumulate(&mut self, chunk: &[u8]) -> Result<()> {
        let block_size = self.store.cfg.block_size as usize;
        let mut rest = chunk;
        while !rest.is_empty() && self.mem_ok {
            // lint:allow(no-panic): `block` is Some from construction until
            // commit consumes the writer; appends cannot run after commit
            let block = self.block.as_mut().expect("mem-leg writer has a block");
            let take = (block_size - block.len()).min(rest.len());
            block.extend_from_slice(&rest[..take]);
            rest = &rest[take..];
            if block.len() == block_size {
                self.seal_block()?;
            }
        }
        Ok(())
    }

    /// Move the accumulator's bytes (a full block, or the final partial
    /// one at commit) into the mode's staging area.
    fn seal_block(&mut self) -> Result<()> {
        // lint:allow(no-panic): `block` is Some from construction until
        // commit consumes the writer; seal_block runs before that point
        let block = self.block.as_mut().expect("mem-leg writer has a block");
        if block.is_empty() {
            return Ok(());
        }
        let bytes: Arc<[u8]> = block[..].to_vec().into();
        block.clear();
        match self.mode {
            WriteMode::MemOnly => self.pending.push(bytes),
            WriteMode::WriteThrough => {
                let skey = BlockId::new(&self.wip, self.staged).storage_key();
                match self.store.mem.put(&skey, bytes) {
                    Ok(evicted) => {
                        self.store.spill_evicted(evicted)?;
                        self.staged += 1;
                    }
                    Err(Error::OverCapacity { .. }) => {
                        // degrade to PFS-only: readers will fault from the
                        // committed checkpoint instead
                        self.mem_ok = false;
                        self.remove_wip();
                    }
                    Err(e) => return Err(e),
                }
            }
            // lint:allow(no-panic): Bypass writers never take the mem leg
            // (`mem_leg` is false), so nothing is ever accumulated to seal
            WriteMode::Bypass => unreachable!("Bypass writers stage no blocks"),
        }
        Ok(())
    }

    fn remove_wip(&mut self) {
        for i in 0..self.staged {
            self.store
                .mem
                .remove(&BlockId::new(&self.wip, i).storage_key());
        }
        self.staged = 0;
    }

    fn commit_inner(&mut self) -> Result<()> {
        self.finished = true;
        let new_blocks = self.store.geometry(self.written).num_blocks();
        // block count of any previous version (overwrite hygiene below;
        // `None` for fresh keys keeps their commits purge-free)
        let old_blocks = self
            .store
            .objects
            .lock()
            .unwrap()
            .get(&self.key)
            .map(|o| self.store.geometry(o.size).num_blocks());
        match self.mode {
            WriteMode::Bypass => {
                // lint:allow(no-panic): Bypass writers are constructed with
                // a PFS leg and nothing else ever takes it
                self.pfs.take().expect("bypass writer has a PFS leg").commit()?;
                if let Some(oldn) = old_blocks {
                    // nothing was cached for the new version: every
                    // resident block of the replaced one is stale
                    self.store
                        .purge_stale_blocks(&self.key, 0, new_blocks.max(oldn));
                }
            }
            WriteMode::WriteThrough => {
                if self.mem_ok {
                    // final partial block; on failure nothing was
                    // published — drop all staging (wip blocks + the PFS
                    // leg's temp datafiles) before surfacing the error
                    if let Err(e) = self.seal_block() {
                        self.remove_wip();
                        if let Some(w) = self.pfs.take() {
                            if let Err(e) = w.abort() {
                                crate::log_warn!(
                                    "write-through rollback `{}`: PFS-leg abort failed: {e}",
                                    self.key
                                );
                            }
                        }
                        return Err(e);
                    }
                }
                // The PFS leg gates the commit (the paper's eq. 6: bounded
                // by the slower tier); if it fails, drop the staging and
                // surface the error — nothing became visible.
                // lint:allow(no-panic): write-through writers are built with
                // a PFS leg; a failed append returns Err before commit, and
                // committing after an Err is outside the writer contract
                let pfs_leg = self.pfs.take().expect("write-through has a PFS leg");
                if let Err(e) = pfs_leg.commit() {
                    self.remove_wip();
                    return Err(e);
                }
                // Swap the staged blocks in under the real key: fresh
                // `.wip/<seq>#i` blocks move as pure Arc moves (no byte
                // copies). Any index *without* a fresh block — degraded
                // leg, eviction mid-write, or a capacity race — instead
                // purges the resident block, so an overwritten object can
                // never serve stale old-version bytes (whose length may
                // not even match the new geometry). Old blocks beyond the
                // new geometry are purged for the same reason.
                let staged = self.staged;
                self.staged = 0;
                let had_old = old_blocks.is_some();
                let old_blocks = old_blocks.unwrap_or(0);
                let mut caching = self.mem_ok;
                let mut move_err = None;
                for i in 0..new_blocks.max(old_blocks) {
                    let fkey = BlockId::new(&self.key, i).storage_key();
                    let fresh = if i < staged {
                        let wkey = BlockId::new(&self.wip, i).storage_key();
                        let b = self.store.mem.peek(&wkey);
                        self.store.mem.remove(&wkey);
                        b
                    } else {
                        None
                    };
                    match fresh {
                        Some(b) if caching && move_err.is_none() => {
                            match self.store.mem.put(&fkey, b) {
                                Ok(evicted) => {
                                    if let Err(e) = self.store.spill_evicted(evicted) {
                                        move_err = Some(e);
                                        self.store.mem.remove(&fkey);
                                    }
                                }
                                Err(_) => {
                                    // capacity race: stop caching, the
                                    // committed PFS object serves reads
                                    caching = false;
                                    self.store.mem.remove(&fkey);
                                }
                            }
                        }
                        _ => {
                            // no fresh block for this index: drop any
                            // stale resident version so reads fall
                            // through to the committed PFS object
                            self.store.mem.remove(&fkey);
                        }
                    }
                }
                if let Some(e) = move_err {
                    // The PFS object landed but a dirty victim of another
                    // object could not spill. Same contract as the v1
                    // "mem leg failed after the PFS leg landed" case:
                    // purge this key's cached blocks (wip staging was
                    // fully drained above), then delete the fresh-key
                    // orphan so restart recovery cannot resurrect a write
                    // that returned `Err` — or, for an overwrite of a
                    // persisted object, commit the fully landed new
                    // version so reads stay self-consistent.
                    for i in 0..new_blocks.max(old_blocks) {
                        self.store
                            .mem
                            .remove(&BlockId::new(&self.key, i).storage_key());
                    }
                    let old = self.store.objects.lock().unwrap().get(&self.key).cloned();
                    match old {
                        Some(o) if o.persisted => {
                            self.store.objects.lock().unwrap().insert(
                                self.key.clone(),
                                ObjEntry {
                                    size: self.written,
                                    persisted: true,
                                },
                            );
                        }
                        _ => {
                            // as in the whole-object path: a failed
                            // fresh-key rollback leaves a resurrectable
                            // orphan — surface it as RecoveryNeeded
                            if let Err(cleanup) = self.store.pfs.delete(&self.key) {
                                return Err(Error::RecoveryNeeded(format!(
                                    "streaming write-through commit of fresh key `{}`: \
                                     victim spill failed ({e}) and the PFS rollback also \
                                     failed ({cleanup}); run recover() before trusting a \
                                     restart",
                                    self.key
                                )));
                            }
                        }
                    }
                    return Err(e);
                }
                if had_old {
                    // fresh blocks replaced the old version in place; its
                    // dirty flags and `.dirty/` spill files are now stale
                    self.store
                        .purge_stale_dirty(&self.key, new_blocks.max(old_blocks));
                }
            }
            WriteMode::MemOnly => {
                self.seal_block()?; // final partial block
                // same over-capacity contract as the whole-object mode (a)
                if self.store.cfg.block_size.min(self.written) > self.store.cfg.mem_capacity {
                    return Err(Error::OverCapacity {
                        need: self.written,
                        capacity: self.store.cfg.mem_capacity,
                    });
                }
                let pending = std::mem::take(&mut self.pending);
                for (i, bytes) in pending.into_iter().enumerate() {
                    let skey = BlockId::new(&self.key, i as u64).storage_key();
                    self.store.dirty.lock().unwrap().insert(skey.clone());
                    let landed = self
                        .store
                        .mem
                        .put(&skey, bytes)
                        .and_then(|evicted| self.store.spill_evicted(evicted));
                    if let Err(e) = landed {
                        // Roll this attempt back: forget dirty flags and
                        // already-landed blocks, so restart recovery
                        // cannot fabricate a ghost entry from stray
                        // `.dirty/` spills of a commit that returned Err.
                        // Spill files at indices inside the *old*
                        // version's geometry are kept — one of them may
                        // be the replaced object's only surviving copy.
                        let keep_spills_below = old_blocks.unwrap_or(0);
                        let mut dirty = self.store.dirty.lock().unwrap();
                        for j in 0..=i {
                            let k = BlockId::new(&self.key, j as u64).storage_key();
                            dirty.remove(&k);
                            self.store.mem.remove(&k);
                        }
                        drop(dirty);
                        for j in 0..=i {
                            if j as u64 >= keep_spills_below {
                                // a stray spill would let restart
                                // recovery fabricate a ghost entry for a
                                // commit that returned Err
                                if let Err(cleanup) = self
                                    .store
                                    .pfs
                                    .delete(&dirty_key(&self.key, j as u64))
                                {
                                    return Err(Error::RecoveryNeeded(format!(
                                        "mem-only commit of `{}` failed ({e}) and spill \
                                         block {j} could not be dropped ({cleanup}); run \
                                         recover() before trusting a restart",
                                        self.key
                                    )));
                                }
                            }
                        }
                        return Err(e);
                    }
                }
                if let Some(oldn) = old_blocks {
                    // shrinking overwrite: the old version's blocks beyond
                    // the new geometry would otherwise stay resident and
                    // dirty forever (their spills orphaned on the PFS)
                    self.store.purge_stale_blocks(&self.key, new_blocks, oldn);
                }
            }
        }
        self.store.objects.lock().unwrap().insert(
            self.key.clone(),
            ObjEntry {
                size: self.written,
                persisted: !matches!(self.mode, WriteMode::MemOnly),
            },
        );
        Ok(())
    }

    /// Push the coalescing carry through both legs, keeping its
    /// allocation for the next batch.
    fn flush_carry(&mut self) -> Result<()> {
        if self.carry.is_empty() {
            return Ok(());
        }
        let mut full = std::mem::take(&mut self.carry);
        self.append_inner(&full)?;
        full.clear();
        self.carry = full;
        Ok(())
    }

    fn abort_inner(&mut self) {
        self.finished = true;
        self.carry.clear();
        self.remove_wip();
        self.pending.clear();
        if let Some(block) = &mut self.block {
            block.clear();
        }
        if let Some(w) = self.pfs.take() {
            // a failed abort leaves staged temps for recover() to reap
            if let Err(e) = w.abort() {
                crate::log_warn!("abort `{}`: PFS-leg cleanup failed: {e}", self.key);
            }
        }
    }
}

impl<P: PfsTier> Drop for TlsWriter<'_, P> {
    fn drop(&mut self) {
        if !self.finished {
            self.abort_inner();
        }
    }
}

impl<P: PfsTier> ObjectWriter for TlsWriter<'_, P> {
    fn append(&mut self, chunk: &[u8]) -> Result<()> {
        if self.coalesce == 0 {
            return self.append_inner(chunk);
        }
        // already-large chunks skip the copy through the carry
        if self.carry.is_empty() && chunk.len() >= self.coalesce {
            return self.append_inner(chunk);
        }
        self.carry.extend_from_slice(chunk);
        if self.carry.len() >= self.coalesce {
            self.flush_carry()?;
        }
        Ok(())
    }

    fn append_vectored(&mut self, parts: &[&[u8]]) -> Result<()> {
        match parts {
            [] => Ok(()),
            [one] => ObjectWriter::append(self, one),
            _ => {
                let total: usize = parts.iter().map(|p| p.len()).sum();
                if self.coalesce != 0 {
                    self.carry.reserve(total);
                    for p in parts {
                        self.carry.extend_from_slice(p);
                    }
                    if self.carry.len() >= self.coalesce {
                        self.flush_carry()?;
                    }
                    Ok(())
                } else {
                    // append-through mode: join once so both legs see a
                    // single chunk large enough for the dual-leg overlap
                    let mut joined = Vec::with_capacity(total);
                    for p in parts {
                        joined.extend_from_slice(p);
                    }
                    self.append_inner(&joined)
                }
            }
        }
    }

    fn written(&self) -> u64 {
        self.written + self.carry.len() as u64
    }

    fn commit(mut self: Box<Self>) -> Result<()> {
        // a coalescing writer may still hold a sub-threshold batch
        if let Err(e) = self.flush_carry() {
            self.abort_inner();
            return Err(e);
        }
        self.commit_inner()
    }

    fn abort(mut self: Box<Self>) -> Result<()> {
        self.abort_inner();
        Ok(())
    }
}

impl<P: PfsTier> Recover for TwoLevelStore<P> {
    fn recover(&self) -> Result<RecoveryReport> {
        TwoLevelStore::<P>::recover(self)
    }
}

impl<P: PfsTier> ObjectStore for TwoLevelStore<P> {
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
        self.open_with(key, ReadMode::TwoLevel)
    }

    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
        self.create_with(key, WriteMode::WriteThrough)
    }

    fn stat(&self, key: &str) -> Result<ObjectMeta> {
        Ok(ObjectMeta {
            key: key.to_string(),
            size: self.entry(key)?.size,
        })
    }

    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        TwoLevelStore::<P>::write(self, key, data, WriteMode::WriteThrough)
    }

    fn read(&self, key: &str) -> Result<Vec<u8>> {
        TwoLevelStore::<P>::read(self, key, ReadMode::TwoLevel)
    }

    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        TwoLevelStore::<P>::read_range(self, key, offset, len, ReadMode::TwoLevel)
    }

    fn size(&self, key: &str) -> Result<u64> {
        Ok(self.entry(key)?.size)
    }

    fn exists(&self, key: &str) -> bool {
        // same cross-process fallback as `entry`: a peer may have
        // committed this key to the shared PFS tier
        self.objects.lock().unwrap().contains_key(key)
            || (!Self::is_reserved_key(key) && self.pfs.exists(key))
    }

    fn delete(&self, key: &str) -> Result<()> {
        let entry = match self.entry(key) {
            Ok(e) => e,
            Err(_) => return Ok(()),
        };
        let geo = self.geometry(entry.size);
        let mut dirty = self.dirty.lock().unwrap();
        let mut spill_err: Option<String> = None;
        for i in 0..geo.num_blocks() {
            let skey = BlockId::new(key, i).storage_key();
            self.mem.remove(&skey);
            dirty.remove(&skey);
            // delete is idempotent for missing spills, so an Err here is a
            // real filesystem failure leaving an orphan `.dirty/` object
            if let Err(e) = self.pfs.delete(&dirty_key(key, i)) {
                crate::log_warn!("delete `{key}`: spill cleanup for block {i} failed: {e}");
                spill_err.get_or_insert_with(|| format!("block {i}: {e}"));
            }
        }
        drop(dirty);
        self.pfs.delete(key)?;
        self.objects.lock().unwrap().remove(key);
        if let Some(e) = spill_err {
            // the object is gone, but its spill orphans need recover()
            return Err(Error::RecoveryNeeded(format!(
                "delete `{key}` left orphan dirty spills ({e})"
            )));
        }
        Ok(())
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .objects
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.sort();
        keys
    }

    fn kind(&self) -> &'static str {
        "tls"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg32;

    fn rand_data(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg32::new(seed, 1);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    fn store(dir: &TempDir, mem_cap: u64, block: u64) -> TwoLevelStore {
        let cfg = TlsConfig::builder(dir.path())
            .mem_capacity(mem_cap)
            .block_size(block)
            .pfs_servers(3)
            .stripe_size(64)
            .pfs_buffer(128)
            .build()
            .unwrap();
        TwoLevelStore::open(cfg).unwrap()
    }

    #[test]
    fn coalescing_writer_matches_append_through_in_every_mode() {
        let data = rand_data(5000, 91);
        for mode in [WriteMode::WriteThrough, WriteMode::Bypass, WriteMode::MemOnly] {
            let dir = TempDir::new("tls-co").unwrap();
            let cfg = TlsConfig::builder(dir.path())
                .mem_capacity(1 << 20)
                .block_size(256)
                .pfs_servers(3)
                .stripe_size(64)
                .pfs_buffer(128)
                .append_coalesce(512)
                .build()
                .unwrap();
            let s = TwoLevelStore::open(cfg).unwrap();
            let mut w = s.create_with("co", mode).unwrap();
            for chunk in data.chunks(33) {
                w.append(chunk).unwrap();
            }
            assert_eq!(w.written(), 5000, "{mode:?}: written() includes the carry");
            w.commit().unwrap();
            assert_eq!(s.read("co", ReadMode::TwoLevel).unwrap(), data, "{mode:?}");

            // vectored form lands identically
            let parts: Vec<&[u8]> = data.chunks(47).collect();
            let mut w = s.create_with("vec", mode).unwrap();
            w.append_vectored(&parts).unwrap();
            w.commit().unwrap();
            assert_eq!(s.read("vec", ReadMode::TwoLevel).unwrap(), data, "{mode:?}");

            // abort with a loaded carry leaves no trace in either tier
            let mut w = s.create_with("ab", mode).unwrap();
            w.append(&data[..100]).unwrap();
            w.abort().unwrap();
            assert!(!s.exists("ab"), "{mode:?}");
            assert!(s.recover().unwrap().is_clean(), "{mode:?}: staged debris");
        }
    }

    #[test]
    fn delete_surfaces_failed_spill_cleanup() {
        // Regression: `delete` used to swallow spill-cleanup errors with
        // `let _ =`, silently leaving orphan `.dirty/` objects behind. A
        // directory planted at the spill's metadata path defeats the
        // unlink, which must now surface as RecoveryNeeded — after the
        // object itself is still fully deleted.
        let dir = TempDir::new("tls-del-spill").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("victim", &rand_data(100, 9), WriteMode::MemOnly).unwrap();
        let meta = dir
            .path()
            .join("pfs")
            .join("meta")
            .join(".dirty%2Fvictim#0.meta");
        std::fs::create_dir_all(&meta).unwrap();
        let err = s.delete("victim").unwrap_err();
        assert!(matches!(err, Error::RecoveryNeeded(_)), "{err}");
        assert!(!s.exists("victim"), "object must be gone despite the error");
        // with the obstruction removed, delete is idempotent and clean
        std::fs::remove_dir(&meta).unwrap();
        s.delete("victim").unwrap();
    }

    #[test]
    fn write_through_lands_in_both_tiers() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1000, 1);
        s.write("obj", &data, WriteMode::WriteThrough).unwrap();
        // read (d): memory only — must fully succeed
        assert_eq!(s.read("obj", ReadMode::MemOnly).unwrap(), data);
        // read (e): PFS only — must also succeed
        assert_eq!(s.read("obj", ReadMode::Bypass).unwrap(), data);
    }

    #[test]
    fn mem_only_write_not_on_pfs_until_checkpoint() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(500, 2);
        s.write("hot", &data, WriteMode::MemOnly).unwrap();
        assert!(matches!(s.read("hot", ReadMode::Bypass), Err(Error::NotFound(_))));
        assert_eq!(s.unpersisted(), vec!["hot"]);
        s.checkpoint("hot").unwrap();
        assert_eq!(s.read("hot", ReadMode::Bypass).unwrap(), data);
        assert!(s.unpersisted().is_empty());
        assert_eq!(s.stats().checkpoints, 1);
    }

    #[test]
    fn bypass_write_skips_memory_tier() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(600, 3);
        s.write("cold", &data, WriteMode::Bypass).unwrap();
        assert!(matches!(s.read("cold", ReadMode::MemOnly), Err(Error::NotFound(_))));
        // two-level read pulls it up and caches it
        assert_eq!(s.read("cold", ReadMode::TwoLevel).unwrap(), data);
        assert_eq!(s.read("cold", ReadMode::MemOnly).unwrap(), data);
    }

    #[test]
    fn two_level_read_mixes_tiers_and_tracks_f() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1024, 4);
        s.write("obj", &data, WriteMode::WriteThrough).unwrap();
        // evict half the blocks from memory
        s.mem().remove("obj#0");
        s.mem().remove("obj#1");
        assert_eq!(s.read("obj", ReadMode::TwoLevel).unwrap(), data);
        let st = s.stats();
        assert_eq!(st.mem_bytes_read, 512);
        assert_eq!(st.pfs_bytes_read, 512);
        assert!((st.f_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dirty_blocks_survive_eviction_pressure() {
        let dir = TempDir::new("tls").unwrap();
        // memory fits only 2 blocks of 256
        let s = store(&dir, 512, 256);
        let a = rand_data(512, 5);
        let b = rand_data(512, 6);
        s.write("a", &a, WriteMode::MemOnly).unwrap();
        s.write("b", &b, WriteMode::MemOnly).unwrap(); // evicts a's blocks
        assert!(s.stats().dirty_spills >= 1);
        // 'a' must still be fully readable (spilled blocks come from PFS)
        assert_eq!(s.read("a", ReadMode::TwoLevel).unwrap(), a);
        assert_eq!(s.read("b", ReadMode::TwoLevel).unwrap(), b);
    }

    #[test]
    fn checkpoint_consolidates_spilled_blocks() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 512, 256);
        let a = rand_data(512, 7);
        s.write("a", &a, WriteMode::MemOnly).unwrap();
        s.write("b", &rand_data(512, 8), WriteMode::MemOnly).unwrap();
        s.checkpoint("a").unwrap();
        assert_eq!(s.read("a", ReadMode::Bypass).unwrap(), a);
        // dirty spill objects cleaned up
        assert!(s.pfs().list(DIRTY_NS).is_empty() || !s.pfs().list(DIRTY_NS).iter().any(|k| k.contains("a#")));
    }

    #[test]
    fn read_range_spans_blocks() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 100);
        let data = rand_data(1000, 9);
        s.write("r", &data, WriteMode::WriteThrough).unwrap();
        for (off, len) in [(0usize, 1000usize), (95, 10), (0, 1), (950, 100), (1000, 4)] {
            let got = s.read_range("r", off as u64, len, ReadMode::TwoLevel).unwrap();
            let end = (off + len).min(1000);
            assert_eq!(got, &data[off.min(1000)..end], "off={off}");
        }
    }

    #[test]
    fn reopen_recovers_persisted_objects() {
        let dir = TempDir::new("tls").unwrap();
        let data = rand_data(700, 10);
        {
            let s = store(&dir, 4096, 256);
            s.write("keep", &data, WriteMode::WriteThrough).unwrap();
        }
        let s = store(&dir, 4096, 256);
        assert!(s.exists("keep"));
        // memory tier is cold: first read comes from the PFS
        assert_eq!(s.read("keep", ReadMode::TwoLevel).unwrap(), data);
        assert!(s.stats().pfs_bytes_read >= 700);
        // second read is hot
        assert_eq!(s.read("keep", ReadMode::TwoLevel).unwrap(), data);
        assert!(s.stats().mem_bytes_read >= 700);
    }

    #[test]
    fn reopen_with_other_block_size_rejected() {
        let dir = TempDir::new("tls").unwrap();
        {
            let _ = store(&dir, 4096, 256);
        }
        let cfg = TlsConfig::builder(dir.path())
            .mem_capacity(4096)
            .block_size(128)
            .build()
            .unwrap();
        assert!(matches!(TwoLevelStore::open(cfg), Err(Error::Config(_))));
    }

    #[test]
    fn reserved_keys_rejected() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        assert!(s.write(".dirty/evil", b"x", WriteMode::Bypass).is_err());
        assert!(s.create_with(".wip/evil", WriteMode::WriteThrough).is_err());
        assert!(s.write(".quarantine/evil", b"x", WriteMode::WriteThrough).is_err());
    }

    #[test]
    fn shuffle_namespace_is_writable_and_reaped_by_recover() {
        // the compute plane's carve-out: `.shuffle/` keys flow through
        // the normal two-level write path (both tiers), and recover()
        // deletes whatever a dead job left there
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 64 << 10, 256);
        let data = rand_data(700, 21);
        s.write(".shuffle/job-1/s0/m00000-p00000-r0", &data, WriteMode::WriteThrough)
            .unwrap();
        assert_eq!(
            s.read(".shuffle/job-1/s0/m00000-p00000-r0", ReadMode::TwoLevel).unwrap(),
            data
        );
        s.write("user/keep", &rand_data(100, 22), WriteMode::WriteThrough).unwrap();
        let report = s.recover().unwrap();
        assert!(report.shuffle_reaped >= 1, "{report}");
        assert!(report.quarantined.is_empty(), "shuffle is dropped, not parked: {report}");
        assert!(s.list(crate::storage::SHUFFLE_NS).is_empty());
        assert!(s.exists("user/keep"));

        // a crashed incarnation's spills are reaped on reboot too
        s.write(".shuffle/job-2/s0/m00001-p00000-r0", &data, WriteMode::WriteThrough)
            .unwrap();
        drop(s);
        let s = store(&dir, 64 << 10, 256);
        assert!(s.exists(".shuffle/job-2/s0/m00001-p00000-r0"), "reopen sees the spill");
        let report = s.recover().unwrap();
        assert!(report.shuffle_reaped >= 1, "{report}");
        assert!(s.list(crate::storage::SHUFFLE_NS).is_empty());
        assert!(s.exists("user/keep"));
        assert!(s.recover().unwrap().is_clean(), "second pass is clean");
    }

    #[test]
    fn delete_cleans_all_tiers() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("d", &rand_data(500, 11), WriteMode::WriteThrough).unwrap();
        ObjectStore::delete(&s, "d").unwrap();
        assert!(!s.exists("d"));
        assert!(matches!(s.read("d", ReadMode::TwoLevel), Err(Error::NotFound(_))));
        assert!(!s.mem().contains("d#0"));
        // idempotent
        ObjectStore::delete(&s, "d").unwrap();
    }

    #[test]
    fn object_store_trait_defaults() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(300, 12);
        ObjectStore::write(&s, "t", &data).unwrap();
        assert_eq!(ObjectStore::read(&s, "t").unwrap(), data);
        assert_eq!(ObjectStore::size(&s, "t").unwrap(), 300);
        assert_eq!(s.list("t"), vec!["t"]);
        assert_eq!(s.kind(), "tls");
    }

    #[test]
    fn empty_object() {
        let dir = TempDir::new("tls").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("e", b"", WriteMode::WriteThrough).unwrap();
        assert_eq!(s.read("e", ReadMode::TwoLevel).unwrap(), Vec::<u8>::new());
        assert_eq!(s.read("e", ReadMode::MemOnly).unwrap(), Vec::<u8>::new());
    }

    // -- v2 handle surface ------------------------------------------------

    #[test]
    fn streaming_writethrough_lands_in_both_tiers() {
        let dir = TempDir::new("tls-w").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1000, 20);
        let mut w = s.create_with("obj", WriteMode::WriteThrough).unwrap();
        for chunk in data.chunks(97) {
            w.append(chunk).unwrap();
        }
        // invisible in every mode until commit
        assert!(!s.exists("obj"));
        assert!(matches!(s.read("obj", ReadMode::TwoLevel), Err(Error::NotFound(_))));
        assert_eq!(w.written(), 1000);
        w.commit().unwrap();
        // staged blocks moved under the real key: full MemOnly read works
        assert_eq!(s.read("obj", ReadMode::MemOnly).unwrap(), data);
        // and the PFS leg streamed the same bytes
        assert_eq!(s.read("obj", ReadMode::Bypass).unwrap(), data);
        // no .wip staging left behind
        assert!(s.mem().list(WIP_NS).is_empty());
    }

    #[test]
    fn streaming_writethrough_dual_leg_large_appends() {
        // appends ≥ 64 KiB fork the PFS leg onto a scoped thread when
        // concurrent_writethrough is set; both knob positions must agree
        for concurrent in [true, false] {
            let dir = TempDir::new("tls-dual").unwrap();
            let cfg = TlsConfig::builder(dir.path())
                .mem_capacity(4 << 20)
                .block_size(64 << 10)
                .pfs_servers(3)
                .stripe_size(16 << 10)
                .concurrent_writethrough(concurrent)
                .build()
                .unwrap();
            let s = TwoLevelStore::open(cfg).unwrap();
            let data = rand_data(300_000, 30);
            let mut w = s.create_with("big", WriteMode::WriteThrough).unwrap();
            for chunk in data.chunks(100_000) {
                w.append(chunk).unwrap();
            }
            w.commit().unwrap();
            assert_eq!(
                s.read("big", ReadMode::MemOnly).unwrap(),
                data,
                "concurrent={concurrent}"
            );
            assert_eq!(
                s.read("big", ReadMode::Bypass).unwrap(),
                data,
                "concurrent={concurrent}"
            );
        }
    }

    #[test]
    fn streaming_memonly_is_dirty_until_checkpoint() {
        let dir = TempDir::new("tls-wm").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(700, 21);
        let mut w = s.create_with("hot", WriteMode::MemOnly).unwrap();
        w.append(&data[..300]).unwrap();
        w.append(&data[300..]).unwrap();
        w.commit().unwrap();
        assert_eq!(s.unpersisted(), vec!["hot"]);
        assert!(matches!(s.read("hot", ReadMode::Bypass), Err(Error::NotFound(_))));
        assert_eq!(s.read("hot", ReadMode::TwoLevel).unwrap(), data);
        s.checkpoint("hot").unwrap();
        assert_eq!(s.read("hot", ReadMode::Bypass).unwrap(), data);
    }

    #[test]
    fn streaming_bypass_skips_memory_tier() {
        let dir = TempDir::new("tls-wb").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(600, 22);
        let mut w = s.create_with("cold", WriteMode::Bypass).unwrap();
        w.append(&data).unwrap();
        w.commit().unwrap();
        assert!(matches!(s.read("cold", ReadMode::MemOnly), Err(Error::NotFound(_))));
        assert_eq!(s.read("cold", ReadMode::TwoLevel).unwrap(), data);
    }

    #[test]
    fn writer_abort_leaves_no_trace_in_either_tier() {
        let dir = TempDir::new("tls-ab").unwrap();
        let s = store(&dir, 4096, 256);
        let used_before = s.mem().used();
        let w = {
            let mut w = s.create_with("gone", WriteMode::WriteThrough).unwrap();
            w.append(&rand_data(900, 23)).unwrap();
            w
        };
        w.abort().unwrap();
        assert!(!s.exists("gone"));
        assert_eq!(s.mem().used(), used_before, "staged blocks freed");
        assert!(s.mem().list(WIP_NS).is_empty());
        assert!(s.pfs().list("").is_empty(), "no PFS object or temp stripes");
    }

    #[test]
    fn overwrite_in_flight_reader_sees_old_object() {
        let dir = TempDir::new("tls-ow").unwrap();
        let s = store(&dir, 4096, 256);
        let v1 = rand_data(800, 24);
        let v2 = rand_data(500, 25);
        s.write("k", &v1, WriteMode::WriteThrough).unwrap();
        let mut w = s.create_with("k", WriteMode::WriteThrough).unwrap();
        w.append(&v2[..250]).unwrap();
        // mid-write: the old object is fully intact in both tiers
        assert_eq!(s.read("k", ReadMode::TwoLevel).unwrap(), v1);
        assert_eq!(s.read("k", ReadMode::Bypass).unwrap(), v1);
        w.append(&v2[250..]).unwrap();
        w.commit().unwrap();
        assert_eq!(s.read("k", ReadMode::TwoLevel).unwrap(), v2);
    }

    #[test]
    fn writethrough_degrades_to_pfs_when_block_exceeds_memory() {
        let dir = TempDir::new("tls-deg").unwrap();
        // memory tier smaller than one block: the streaming mem leg must
        // step aside, the PFS leg still commits
        let s = store(&dir, 100, 256);
        let data = rand_data(1000, 26);
        let mut w = s.create_with("big", WriteMode::WriteThrough).unwrap();
        for chunk in data.chunks(300) {
            w.append(chunk).unwrap();
        }
        w.commit().unwrap();
        assert_eq!(s.read("big", ReadMode::Bypass).unwrap(), data);
        assert!(s.mem().used() <= 100);
        assert!(s.mem().list(WIP_NS).is_empty());
    }

    #[test]
    fn degraded_overwrite_purges_stale_cached_blocks() {
        let dir = TempDir::new("tls-deg-ow").unwrap();
        // memory holds the old 50-byte object but not one new 256-byte
        // block: the overwrite's mem leg degrades, and commit must purge
        // the stale v1 block instead of letting reads serve it
        let s = store(&dir, 100, 256);
        let v1 = rand_data(50, 33);
        s.write("k", &v1, WriteMode::WriteThrough).unwrap();
        assert!(s.mem().contains("k#0"));
        let v2 = rand_data(1000, 34);
        let mut w = s.create_with("k", WriteMode::WriteThrough).unwrap();
        for chunk in v2.chunks(300) {
            w.append(chunk).unwrap();
        }
        w.commit().unwrap();
        assert!(!s.mem().contains("k#0"), "stale v1 block must be purged");
        assert_eq!(s.read("k", ReadMode::Bypass).unwrap(), v2);
        // MemOnly now reports a clean miss — never stale v1 bytes
        assert!(matches!(s.read("k", ReadMode::MemOnly), Err(Error::NotFound(_))));
    }

    #[test]
    fn evicted_wip_overwrite_purges_stale_cached_blocks() {
        let dir = TempDir::new("tls-ev-ow").unwrap();
        // memory holds exactly one new block: wip staging evicts itself
        // rolling forward, so most indices have no fresh block at commit —
        // those must purge any stale resident version, not skip it
        let s = store(&dir, 300, 256);
        let v1 = rand_data(50, 35);
        s.write("k", &v1, WriteMode::WriteThrough).unwrap();
        let v2 = rand_data(1000, 36);
        let mut w = s.create_with("k", WriteMode::WriteThrough).unwrap();
        for chunk in v2.chunks(300) {
            w.append(chunk).unwrap();
        }
        w.commit().unwrap();
        // every read path serves v2 exactly; no mixed-version bytes
        assert_eq!(s.read("k", ReadMode::Bypass).unwrap(), v2);
        assert_eq!(s.read("k", ReadMode::TwoLevel).unwrap(), v2);
        assert!(s.mem().list(WIP_NS).is_empty(), "no wip leak after commit");
    }

    #[test]
    fn bypass_overwrite_purges_stale_cached_blocks() {
        let dir = TempDir::new("tls-byp-ow").unwrap();
        let s = store(&dir, 4096, 256);
        let v1 = rand_data(50, 37);
        s.write("k", &v1, WriteMode::WriteThrough).unwrap();
        assert!(s.mem().contains("k#0"));
        // v1 whole-object Bypass overwrite: caches nothing, so the stale
        // v1 block must be purged or TwoLevel reads would serve it
        let v2 = rand_data(1000, 38);
        s.write("k", &v2, WriteMode::Bypass).unwrap();
        assert!(!s.mem().contains("k#0"), "stale block purged (v1 path)");
        assert_eq!(s.read("k", ReadMode::TwoLevel).unwrap(), v2);

        // same contract through the streaming Bypass writer
        s.write("j", &v1, WriteMode::WriteThrough).unwrap();
        assert!(s.mem().contains("j#0"));
        let mut w = s.create_with("j", WriteMode::Bypass).unwrap();
        w.append(&v2).unwrap();
        w.commit().unwrap();
        assert!(!s.mem().contains("j#0"), "stale block purged (handle path)");
        assert_eq!(s.read("j", ReadMode::TwoLevel).unwrap(), v2);
    }

    #[test]
    fn memonly_shrinking_overwrite_drops_stale_dirty_blocks() {
        let dir = TempDir::new("tls-shrink").unwrap();
        let s = store(&dir, 4096, 256);
        let big = rand_data(1000, 39); // 4 dirty blocks
        s.write("k", &big, WriteMode::MemOnly).unwrap();
        let small = rand_data(100, 40); // 1 dirty block
        s.write("k", &small, WriteMode::MemOnly).unwrap();
        // old blocks beyond the new geometry are gone from the tier
        for i in 1..4 {
            assert!(!s.mem().contains(&format!("k#{i}")), "stale dirty block {i}");
        }
        s.checkpoint("k").unwrap();
        assert_eq!(s.read("k", ReadMode::Bypass).unwrap(), small);
        // and no orphaned spill objects survive in the dirty namespace
        assert!(s.pfs().list(DIRTY_NS).is_empty());
    }

    #[test]
    fn reader_modes_and_eof_clamping() {
        let dir = TempDir::new("tls-r").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1000, 27);
        s.write("r", &data, WriteMode::WriteThrough).unwrap();

        let r = s.open_with("r", ReadMode::TwoLevel).unwrap();
        assert_eq!(r.len(), 1000);
        for (off, len) in [(0usize, 1000usize), (250, 20), (255, 2), (999, 1), (900, 500)] {
            let mut buf = vec![0u8; len];
            let n = r.read_at(off as u64, &mut buf).unwrap();
            let end = (off + len).min(1000);
            assert_eq!(n, end - off, "off={off}");
            assert_eq!(&buf[..n], &data[off..end], "off={off}");
        }
        let mut buf = [0u8; 8];
        assert_eq!(r.read_at(1000, &mut buf).unwrap(), 0);
        drop(r);

        // MemOnly reader errors once a block is evicted
        let r = s.open_with("r", ReadMode::MemOnly).unwrap();
        let mut one = vec![0u8; 10];
        assert_eq!(r.read_at(0, &mut one).unwrap(), 10);
        s.mem().remove("r#0");
        assert!(matches!(r.read_at(0, &mut one), Err(Error::NotFound(_))));
        drop(r);

        // TwoLevel reader faults only touched blocks back in
        let r = s.open_with("r", ReadMode::TwoLevel).unwrap();
        assert_eq!(r.read_at(0, &mut one).unwrap(), 10);
        assert!(s.mem().contains("r#0"), "touched block cached");

        // Bypass reader on an unpersisted object is refused at open
        s.write("m", &rand_data(100, 28), WriteMode::MemOnly).unwrap();
        assert!(matches!(
            s.open_with("m", ReadMode::Bypass),
            Err(Error::NotFound(_))
        ));
    }

    #[test]
    fn stat_subsumes_size_and_exists() {
        let dir = TempDir::new("tls-st").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("a", &rand_data(321, 29), WriteMode::WriteThrough).unwrap();
        let meta = ObjectStore::stat(&s, "a").unwrap();
        assert_eq!(meta.key, "a");
        assert_eq!(meta.size, 321);
        assert!(matches!(ObjectStore::stat(&s, "nope"), Err(Error::NotFound(_))));
    }

    // -- crash recovery ----------------------------------------------------

    #[test]
    fn recover_on_clean_store_is_clean() {
        let dir = TempDir::new("tls-rec0").unwrap();
        let s = store(&dir, 4096, 256);
        s.write("a", &rand_data(700, 50), WriteMode::WriteThrough).unwrap();
        s.write("b", &rand_data(100, 51), WriteMode::MemOnly).unwrap();
        let report = s.recover().unwrap();
        assert!(report.is_clean(), "{report}");
        // live unpersisted object untouched by recovery
        assert_eq!(s.read("b", ReadMode::TwoLevel).unwrap(), rand_data(100, 51));
    }

    #[test]
    fn uncheckpointed_memonly_object_is_not_resurrected_after_reboot() {
        let dir = TempDir::new("tls-rec1").unwrap();
        let a = rand_data(512, 52);
        {
            // memory fits 2 blocks: writing `b` evicts and spills both of
            // `a`'s dirty blocks to the PFS `.dirty/` namespace
            let s = store(&dir, 512, 256);
            s.write("a", &a, WriteMode::MemOnly).unwrap();
            s.write("b", &rand_data(512, 53), WriteMode::MemOnly).unwrap();
            assert!(s.stats().dirty_spills >= 2);
            assert_eq!(s.read("a", ReadMode::TwoLevel).unwrap(), a, "alive pre-crash");
        } // crash: the process dies; the memory tier evaporates
        let s = store(&dir, 512, 256);
        // mode-(a) data was never checkpointed: it must NOT come back —
        // not as a prefix, not even though every spill block survived
        assert!(!s.exists("a"), "volatile object resurrected");
        assert!(!s.exists("b"));
        let report = s.recover().unwrap();
        assert!(report.quarantined.len() >= 2, "{report}");
        assert!(s.pfs().list(DIRTY_NS).is_empty(), "spills quarantined");
        assert!(matches!(s.read("a", ReadMode::TwoLevel), Err(Error::NotFound(_))));
        // second pass is clean
        assert!(s.recover().unwrap().is_clean());
    }

    #[test]
    fn checkpointed_object_survives_reboot_and_stale_spills_drop() {
        let dir = TempDir::new("tls-rec2").unwrap();
        let a = rand_data(512, 54);
        {
            let s = store(&dir, 4096, 256);
            s.write("a", &a, WriteMode::MemOnly).unwrap();
            s.checkpoint("a").unwrap();
            // craft a stale spill a crash could have left behind (the
            // checkpoint normally deletes these; simulate dying between
            // the checkpoint commit and the spill cleanup)
            s.pfs().write(&dirty_key("a", 0), &a[..256]).unwrap();
        }
        let s = store(&dir, 4096, 256);
        assert!(s.exists("a"), "checkpointed object survives");
        let report = s.recover().unwrap();
        assert_eq!(report.spills_dropped, 1, "{report}");
        assert!(report.quarantined.is_empty());
        assert_eq!(s.read("a", ReadMode::TwoLevel).unwrap(), a);
        assert!(s.pfs().list(DIRTY_NS).is_empty());
    }

    #[test]
    fn recover_drops_abandoned_wip_staging() {
        let dir = TempDir::new("tls-rec3").unwrap();
        let s = store(&dir, 4096, 256);
        // a leaked writer's staging block (its process died mid-stream)
        s.mem().put(&format!("{WIP_NS}99#0"), vec![1u8; 64].into()).unwrap();
        let used = s.mem().used();
        let report = s.recover().unwrap();
        assert_eq!(report.temps_removed, 1, "{report}");
        assert!(s.mem().list(WIP_NS).is_empty());
        assert_eq!(s.mem().used(), used - 64);
    }

    #[test]
    fn quarantined_checkpoint_drops_the_object_entry() {
        let dir = TempDir::new("tls-rec4").unwrap();
        let s = store(&dir, 4096, 256);
        let data = rand_data(1000, 55);
        s.write("k", &data, WriteMode::WriteThrough).unwrap();
        assert!(s.mem().contains("k#0"));
        // bit-rot in one PFS datafile: the checkpoint is inconsistent
        let df = dir.path().join("pfs").join("server0").join("k.df");
        let mut bytes = std::fs::read(&df).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&df, bytes).unwrap();
        let report = s.recover().unwrap();
        assert_eq!(report.quarantined, vec!["k".to_string()], "{report}");
        // the key reads NotFound everywhere — never corrupt bytes, and no
        // stale cached blocks survive the quarantine
        assert!(!s.exists("k"));
        assert!(!s.mem().contains("k#0"), "cached blocks purged");
        assert!(matches!(s.read("k", ReadMode::TwoLevel), Err(Error::NotFound(_))));
    }

    #[test]
    fn large_object_exceeding_memory_two_level_reads() {
        let dir = TempDir::new("tls").unwrap();
        // 1 KiB memory, 4 KiB object: mode (f) with capacity slope (Fig 6)
        let s = store(&dir, 1024, 256);
        let data = rand_data(4096, 13);
        s.write("big", &data, WriteMode::WriteThrough).unwrap();
        assert_eq!(s.read("big", ReadMode::TwoLevel).unwrap(), data);
        let st = s.stats();
        assert!(st.pfs_bytes_read > 0, "must have spilled to PFS");
        assert!(s.mem().used() <= 1024);
    }
}
