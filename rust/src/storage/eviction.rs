//! Block eviction policies for the memory tier.
//!
//! §3.2: "caching reusable data to improve read performance with a matched
//! data eviction policy, such as LRU/LFU". Both are implemented behind one
//! trait so the ablation bench can swap them per run.

use std::collections::{BTreeSet, HashMap};

/// Eviction bookkeeping. The memstore calls the hooks; `victim` names the
/// next block to drop when capacity is exceeded.
pub trait EvictionPolicy: Send {
    /// A key was inserted (counts as an access).
    fn on_insert(&mut self, key: &str);
    /// A key was read.
    fn on_access(&mut self, key: &str);
    /// A key was removed externally (delete or eviction completes).
    fn on_remove(&mut self, key: &str);
    /// Next victim, or `None` if empty. Must be a currently-tracked key.
    fn victim(&mut self) -> Option<String>;
    /// Policy name (for metrics/benches).
    fn name(&self) -> &'static str;
}

/// Build a policy by name (`lru` | `lfu`).
pub fn by_name(name: &str) -> Option<Box<dyn EvictionPolicy>> {
    match name {
        "lru" => Some(Box::new(Lru::new())),
        "lfu" => Some(Box::new(Lfu::new())),
        _ => None,
    }
}

/// Least-recently-used: victims in order of last access.
pub struct Lru {
    tick: u64,
    last_use: HashMap<String, u64>,
    order: BTreeSet<(u64, String)>,
}

impl Lru {
    /// An empty LRU policy.
    pub fn new() -> Self {
        Self {
            tick: 0,
            last_use: HashMap::new(),
            order: BTreeSet::new(),
        }
    }

    fn touch(&mut self, key: &str) {
        self.tick += 1;
        if let Some(old) = self.last_use.insert(key.to_string(), self.tick) {
            self.order.remove(&(old, key.to_string()));
        }
        self.order.insert((self.tick, key.to_string()));
    }
}

impl Default for Lru {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for Lru {
    fn on_insert(&mut self, key: &str) {
        self.touch(key);
    }
    fn on_access(&mut self, key: &str) {
        self.touch(key);
    }
    fn on_remove(&mut self, key: &str) {
        if let Some(old) = self.last_use.remove(key) {
            self.order.remove(&(old, key.to_string()));
        }
    }
    fn victim(&mut self) -> Option<String> {
        self.order.iter().next().map(|(_, k)| k.clone())
    }
    fn name(&self) -> &'static str {
        "lru"
    }
}

/// Least-frequently-used with LRU tie-break.
pub struct Lfu {
    tick: u64,
    state: HashMap<String, (u64, u64)>, // key -> (freq, last tick)
    order: BTreeSet<(u64, u64, String)>, // (freq, last tick, key)
}

impl Lfu {
    /// An empty LFU policy.
    pub fn new() -> Self {
        Self {
            tick: 0,
            state: HashMap::new(),
            order: BTreeSet::new(),
        }
    }

    fn bump(&mut self, key: &str, df: u64) {
        self.tick += 1;
        let (freq, last) = self.state.get(key).copied().unwrap_or((0, 0));
        if freq != 0 || last != 0 || self.state.contains_key(key) {
            self.order.remove(&(freq, last, key.to_string()));
        }
        let nf = freq + df;
        self.state.insert(key.to_string(), (nf, self.tick));
        self.order.insert((nf, self.tick, key.to_string()));
    }
}

impl Default for Lfu {
    fn default() -> Self {
        Self::new()
    }
}

impl EvictionPolicy for Lfu {
    fn on_insert(&mut self, key: &str) {
        self.bump(key, 1);
    }
    fn on_access(&mut self, key: &str) {
        self.bump(key, 1);
    }
    fn on_remove(&mut self, key: &str) {
        if let Some((f, l)) = self.state.remove(key) {
            self.order.remove(&(f, l, key.to_string()));
        }
    }
    fn victim(&mut self) -> Option<String> {
        self.order.iter().next().map(|(_, _, k)| k.clone())
    }
    fn name(&self) -> &'static str {
        "lfu"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves() {
        assert_eq!(by_name("lru").unwrap().name(), "lru");
        assert_eq!(by_name("lfu").unwrap().name(), "lfu");
        assert!(by_name("fifo").is_none());
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut p = Lru::new();
        p.on_insert("a");
        p.on_insert("b");
        p.on_insert("c");
        p.on_access("a"); // now b is the oldest
        assert_eq!(p.victim().unwrap(), "b");
        p.on_remove("b");
        assert_eq!(p.victim().unwrap(), "c");
    }

    #[test]
    fn lru_remove_unknown_is_noop() {
        let mut p = Lru::new();
        p.on_remove("ghost");
        assert!(p.victim().is_none());
    }

    #[test]
    fn lfu_evicts_least_frequent() {
        let mut p = Lfu::new();
        p.on_insert("hot");
        p.on_insert("cold");
        for _ in 0..5 {
            p.on_access("hot");
        }
        assert_eq!(p.victim().unwrap(), "cold");
        p.on_remove("cold");
        assert_eq!(p.victim().unwrap(), "hot");
    }

    #[test]
    fn lfu_ties_break_lru() {
        let mut p = Lfu::new();
        p.on_insert("first");
        p.on_insert("second");
        // equal frequency → older last-use goes first
        assert_eq!(p.victim().unwrap(), "first");
    }

    #[test]
    fn policies_track_reinsertion() {
        for mut p in [by_name("lru").unwrap(), by_name("lfu").unwrap()] {
            p.on_insert("x");
            p.on_remove("x");
            assert!(p.victim().is_none(), "{}", p.name());
            p.on_insert("x");
            assert_eq!(p.victim().unwrap(), "x");
        }
    }

    #[test]
    fn victim_is_stable_without_updates() {
        let mut p = Lru::new();
        p.on_insert("a");
        p.on_insert("b");
        assert_eq!(p.victim().unwrap(), "a");
        assert_eq!(p.victim().unwrap(), "a");
    }
}
