//! Deterministic fault injection for the storage layer.
//!
//! The paper's two-level design is only sound if the durable tier survives
//! the memory tier (and the process around it) dying at any instant. This
//! module turns that from an assertion into something testable: a
//! [`FaultPlan`] describes *exactly* which operation of a run should fail
//! and how, and [`FaultStore`] wraps any [`ObjectStore`] so the plan fires
//! on the real API surface — `open`/`create`/`stat`/`delete` at the store,
//! `read_at` on readers, `append`/`commit`/`abort` on writers.
//!
//! Faults are deterministic: a trigger names an operation kind, fires on
//! the N-th matching call (optionally restricted to keys containing a
//! substring, or to reads/appends at or past a byte offset), and fires
//! exactly once. Plans can be built explicitly ([`FaultPlan::crash_at`],
//! [`FaultPlan::fail_at`]), parsed from a spec string (the CLI's
//! `--fault-plan`, see [`FaultPlan::parse`]), or derived from a seed via
//! [`crate::util::rng`] ([`FaultPlan::seeded`]) for randomized
//! crash-recovery property tests.
//!
//! ## Fault kinds
//!
//! - [`FaultKind::Error`] — the operation returns [`Error::Injected`]
//!   without touching the inner store. Writers stay abortable, so a
//!   failed operation leaves no partial visibility.
//! - [`FaultKind::ShortRead`] — `read_at` serves fewer bytes than the
//!   caller asked for (still ≥ 1 before EOF). Exercises every caller's
//!   retry loop; [`crate::storage::read_full_at`] must reassemble exactly.
//! - [`FaultKind::CorruptRead`] — `read_at` succeeds but the first byte of
//!   the served range is flipped, simulating bit rot under a CRC.
//! - [`FaultKind::Crash`] — the simulated process dies: the in-flight
//!   handle is *abandoned* (its destructor never runs, exactly like a
//!   `kill -9`, so temp datafiles / staging stay on disk), and every
//!   subsequent operation through this wrapper returns
//!   [`Error::Injected`]. The surviving directory tree is what a
//!   restart's `recover()` (see [`crate::storage::Recover`]) must repair.
//!
//! A read-only fault kind attached to a non-read operation degrades to
//! [`FaultKind::Error`] — seeded plans may produce such pairs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::storage::{ObjectMeta, ObjectReader, ObjectStore, ObjectWriter};
use crate::util::rng::Pcg32;

/// What an injected fault does; see the module docs for semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the operation with [`Error::Injected`].
    Error,
    /// Serve fewer bytes than requested (reads only).
    ShortRead,
    /// Flip a byte in the served range (reads only).
    CorruptRead,
    /// Abandon the in-flight handle and refuse all further operations.
    Crash,
}

impl FaultKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "error" => Some(FaultKind::Error),
            "short-read" | "short" => Some(FaultKind::ShortRead),
            "corrupt" | "corrupt-read" => Some(FaultKind::CorruptRead),
            "crash" => Some(FaultKind::Crash),
            _ => None,
        }
    }

    /// Spec-string name (inverse of the parser).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::ShortRead => "short-read",
            FaultKind::CorruptRead => "corrupt",
            FaultKind::Crash => "crash",
        }
    }
}

/// The operation a trigger watches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Opening an existing object for read.
    Open,
    /// Creating a staged writer.
    Create,
    /// Existence/length query.
    Stat,
    /// Object deletion.
    Delete,
    /// Positional read.
    ReadAt,
    /// Staged append.
    Append,
    /// Writer commit (rename into place).
    Commit,
    /// Writer abort (cleanup of staging state).
    Abort,
}

impl OpKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "open" => Some(OpKind::Open),
            "create" => Some(OpKind::Create),
            "stat" => Some(OpKind::Stat),
            "delete" => Some(OpKind::Delete),
            "read" | "read-at" => Some(OpKind::ReadAt),
            "append" => Some(OpKind::Append),
            "commit" => Some(OpKind::Commit),
            "abort" => Some(OpKind::Abort),
            _ => None,
        }
    }

    /// Spec-string name (inverse of the parser).
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Open => "open",
            OpKind::Create => "create",
            OpKind::Stat => "stat",
            OpKind::Delete => "delete",
            OpKind::ReadAt => "read",
            OpKind::Append => "append",
            OpKind::Commit => "commit",
            OpKind::Abort => "abort",
        }
    }
}

/// One armed fault: fires once, on the `after`-indexed matching operation.
#[derive(Debug, Clone)]
pub struct Trigger {
    /// Operation kind this trigger watches.
    pub op: OpKind,
    /// Fire on the (`after`+1)-th matching operation (0 = the first).
    pub after: u64,
    /// Only operations whose key contains this substring match.
    pub key_pattern: Option<String>,
    /// Only reads/appends at or past this object byte offset match
    /// (ignored for operations that carry no offset).
    pub min_offset: Option<u64>,
    /// What happens when the trigger fires.
    pub kind: FaultKind,
}

/// A deterministic set of [`Trigger`]s. Cloning a plan re-arms it (the
/// per-trigger match counters live in the [`FaultStore`], not the plan).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// The armed triggers; each fires at most once.
    pub triggers: Vec<Trigger>,
}

impl FaultPlan {
    /// An empty plan (injects nothing).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a trigger (builder style).
    pub fn with(mut self, t: Trigger) -> Self {
        self.triggers.push(t);
        self
    }

    /// Crash on the (`after`+1)-th `op`.
    pub fn crash_at(op: OpKind, after: u64) -> Self {
        Self::new().with(Trigger {
            op,
            after,
            key_pattern: None,
            min_offset: None,
            kind: FaultKind::Crash,
        })
    }

    /// Fail (with [`Error::Injected`]) the (`after`+1)-th `op`.
    pub fn fail_at(op: OpKind, after: u64) -> Self {
        Self::new().with(Trigger {
            op,
            after,
            key_pattern: None,
            min_offset: None,
            kind: FaultKind::Error,
        })
    }

    /// Derive a single-trigger plan deterministically from `seed`
    /// (workhorse of the randomized crash-recovery property tests; the
    /// same seed always yields the same plan). Triggers are biased toward
    /// the write path, where crash consistency lives.
    pub fn seeded(seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0xFA_17);
        let op = [
            OpKind::Append,
            OpKind::Commit,
            OpKind::Append,
            OpKind::Commit,
            OpKind::Create,
            OpKind::Delete,
        ][rng.gen_range(6) as usize];
        let kind = [
            FaultKind::Crash,
            FaultKind::Crash,
            FaultKind::Error,
            FaultKind::Crash,
        ][rng.gen_range(4) as usize];
        Self::new().with(Trigger {
            op,
            after: rng.gen_range(12) as u64,
            key_pattern: None,
            min_offset: None,
            kind,
        })
    }

    /// Parse a spec string: `;`-separated triggers, each a `,`-separated
    /// list of `key=value` fields. Fields: `op` (required —
    /// `open|create|stat|delete|read|append|commit|abort`), `kind`
    /// (`error|short-read|corrupt|crash`, default `error`), `after`
    /// (default 0), `key` (substring filter), `offset` (minimum byte
    /// offset).
    ///
    /// Example: `--fault-plan "op=commit,kind=crash,after=2,key=part"`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = Self::new();
        for trigger_spec in spec.split(';') {
            let trigger_spec = trigger_spec.trim();
            if trigger_spec.is_empty() {
                continue;
            }
            let mut op = None;
            let mut kind = FaultKind::Error;
            let mut after = 0u64;
            let mut key_pattern = None;
            let mut min_offset = None;
            for field in trigger_spec.split(',') {
                let (k, v) = field
                    .trim()
                    .split_once('=')
                    .ok_or_else(|| Error::InvalidArg(format!("fault-plan field `{field}` is not key=value")))?;
                match k.trim() {
                    "op" => {
                        op = Some(OpKind::parse(v.trim()).ok_or_else(|| {
                            Error::InvalidArg(format!("unknown fault-plan op `{v}`"))
                        })?)
                    }
                    "kind" => {
                        kind = FaultKind::parse(v.trim()).ok_or_else(|| {
                            Error::InvalidArg(format!("unknown fault-plan kind `{v}`"))
                        })?
                    }
                    "after" => {
                        after = v.trim().parse().map_err(|_| {
                            Error::InvalidArg(format!("bad fault-plan after `{v}`"))
                        })?
                    }
                    "key" => key_pattern = Some(v.trim().to_string()),
                    "offset" => {
                        min_offset = Some(v.trim().parse().map_err(|_| {
                            Error::InvalidArg(format!("bad fault-plan offset `{v}`"))
                        })?)
                    }
                    other => {
                        return Err(Error::InvalidArg(format!(
                            "unknown fault-plan field `{other}`"
                        )))
                    }
                }
            }
            let op = op
                .ok_or_else(|| Error::InvalidArg("fault-plan trigger needs an `op=` field".into()))?;
            plan.triggers.push(Trigger {
                op,
                after,
                key_pattern,
                min_offset,
                kind,
            });
        }
        if plan.triggers.is_empty() {
            return Err(Error::InvalidArg("empty fault plan".into()));
        }
        Ok(plan)
    }
}

/// Counters of faults that actually fired.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Operations that returned an injected error.
    pub injected_errors: u64,
    /// Reads truncated by a short-read fault.
    pub short_reads: u64,
    /// Reads corrupted by a bit-flip fault.
    pub corruptions: u64,
    /// Simulated crashes (writer abandoned mid-operation).
    pub crashes: u64,
}

/// Trigger state + crash flag, shared between the store and its handles.
struct Shared {
    /// Each trigger paired with how many matching operations it has seen.
    triggers: Mutex<Vec<(Trigger, u64)>>,
    crashed: AtomicBool,
    injected_errors: AtomicU64,
    short_reads: AtomicU64,
    corruptions: AtomicU64,
    crashes: AtomicU64,
}

impl Shared {
    /// Account one operation: `Err` if the store already crashed, else the
    /// fault kind to apply now (if any trigger fires).
    fn observe(&self, op: OpKind, key: &str, offset: Option<u64>) -> Result<Option<FaultKind>> {
        if self.crashed.load(Ordering::SeqCst) {
            return Err(Error::Injected(format!(
                "store is down (simulated crash): {} on `{key}` refused",
                op.name()
            )));
        }
        let mut fired = None;
        let mut g = self.triggers.lock().unwrap();
        for (t, seen) in &mut *g {
            if t.op != op {
                continue;
            }
            if let Some(p) = &t.key_pattern {
                if !key.contains(p.as_str()) {
                    continue;
                }
            }
            if let Some(min) = t.min_offset {
                match offset {
                    Some(o) if o >= min => {}
                    _ => continue,
                }
            }
            let n = *seen;
            *seen += 1;
            if n == t.after && fired.is_none() {
                fired = Some(t.kind);
            }
        }
        Ok(fired)
    }

    /// Fire a non-read fault: record it and build the error to return.
    /// `Crash` also poisons the wrapper; the caller abandons its handle.
    fn trip(&self, kind: FaultKind, op: OpKind, key: &str) -> Error {
        match kind {
            FaultKind::Crash => {
                self.crashed.store(true, Ordering::SeqCst);
                self.crashes.fetch_add(1, Ordering::Relaxed);
                Error::Injected(format!(
                    "simulated crash during {} on `{key}`",
                    op.name()
                ))
            }
            // ShortRead / CorruptRead degrade to Error off the read path
            _ => {
                self.injected_errors.fetch_add(1, Ordering::Relaxed);
                Error::Injected(format!("injected {} failure on `{key}`", op.name()))
            }
        }
    }
}

/// An [`ObjectStore`] wrapper that injects the faults of a [`FaultPlan`]
/// into the wrapped backend's operations. See the module docs for the
/// fault semantics; [`FaultStore::stats`] reports what actually fired and
/// [`FaultStore::crashed`] whether the simulated process is down.
///
/// `S` is any `ObjectStore` — owned (`FaultStore<Pfs>`), borrowed
/// (`FaultStore<&Pfs>`), or dynamic (`FaultStore<Arc<dyn ObjectStore>>`),
/// thanks to the forwarding impls on `&T`/`Box<T>`/`Arc<T>` in
/// [`crate::storage`].
pub struct FaultStore<S> {
    inner: S,
    shared: Arc<Shared>,
}

impl<S: ObjectStore> FaultStore<S> {
    /// Wrap `inner`, arming `plan`'s triggers.
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        Self {
            inner,
            shared: Arc::new(Shared {
                triggers: Mutex::new(plan.triggers.into_iter().map(|t| (t, 0)).collect()),
                crashed: AtomicBool::new(false),
                injected_errors: AtomicU64::new(0),
                short_reads: AtomicU64::new(0),
                corruptions: AtomicU64::new(0),
                crashes: AtomicU64::new(0),
            }),
        }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// Whether a [`FaultKind::Crash`] has fired (every further operation
    /// returns [`Error::Injected`]).
    pub fn crashed(&self) -> bool {
        self.shared.crashed.load(Ordering::SeqCst)
    }

    /// Counters of faults that fired so far.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            injected_errors: self.shared.injected_errors.load(Ordering::Relaxed),
            short_reads: self.shared.short_reads.load(Ordering::Relaxed),
            corruptions: self.shared.corruptions.load(Ordering::Relaxed),
            crashes: self.shared.crashes.load(Ordering::Relaxed),
        }
    }

    /// Observe a store-level op; `Err` when a fault fires (or the store
    /// is already down).
    fn gate(&self, op: OpKind, key: &str) -> Result<()> {
        match self.shared.observe(op, key, None)? {
            None => Ok(()),
            Some(kind) => Err(self.shared.trip(kind, op, key)),
        }
    }
}

impl<S: ObjectStore> ObjectStore for FaultStore<S> {
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
        self.gate(OpKind::Open, key)?;
        Ok(Box::new(FaultReader {
            inner: self.inner.open(key)?,
            shared: Arc::clone(&self.shared),
            key: key.to_string(),
        }))
    }

    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
        self.gate(OpKind::Create, key)?;
        Ok(Box::new(FaultWriter {
            inner: Some(self.inner.create(key)?),
            shared: Arc::clone(&self.shared),
            key: key.to_string(),
            written: 0,
        }))
    }

    fn stat(&self, key: &str) -> Result<ObjectMeta> {
        self.gate(OpKind::Stat, key)?;
        self.inner.stat(key)
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.gate(OpKind::Delete, key)?;
        self.inner.delete(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        if self.shared.crashed.load(Ordering::SeqCst) {
            return Vec::new(); // a dead store lists nothing
        }
        self.inner.list(prefix)
    }

    fn kind(&self) -> &'static str {
        "fault"
    }

    // v1 adapters are *not* overridden: every whole-object call funnels
    // through the faulty handles, so one plan covers both API surfaces.
}

/// Reader wrapper applying read-path faults; see [`FaultStore`].
pub struct FaultReader<'a> {
    inner: Box<dyn ObjectReader + 'a>,
    shared: Arc<Shared>,
    key: String,
}

impl ObjectReader for FaultReader<'_> {
    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        match self.shared.observe(OpKind::ReadAt, &self.key, Some(offset))? {
            None => self.inner.read_at(offset, buf),
            Some(FaultKind::ShortRead) => {
                self.shared.short_reads.fetch_add(1, Ordering::Relaxed);
                // serve at most half the request, but ≥ 1 byte so callers
                // looping on read_at still make progress toward EOF
                let short = if buf.len() <= 1 { buf.len() } else { buf.len() / 2 };
                self.inner.read_at(offset, &mut buf[..short])
            }
            Some(FaultKind::CorruptRead) => {
                let n = self.inner.read_at(offset, buf)?;
                if n > 0 {
                    buf[0] ^= 0xFF;
                    self.shared.corruptions.fetch_add(1, Ordering::Relaxed);
                }
                Ok(n)
            }
            Some(kind) => Err(self.shared.trip(kind, OpKind::ReadAt, &self.key)),
        }
    }
}

/// Writer wrapper applying write-path faults; see [`FaultStore`]. On a
/// [`FaultKind::Crash`] the wrapped writer is abandoned via
/// [`std::mem::forget`] — its destructor (which would clean temp files)
/// deliberately never runs, leaving the on-disk debris a killed process
/// would leave.
pub struct FaultWriter<'a> {
    inner: Option<Box<dyn ObjectWriter + 'a>>,
    shared: Arc<Shared>,
    key: String,
    written: u64,
}

impl FaultWriter<'_> {
    /// Abandon the inner writer without running its destructor (the
    /// simulated `kill -9`).
    fn abandon(&mut self) {
        if let Some(w) = self.inner.take() {
            std::mem::forget(w);
        }
    }
}

impl ObjectWriter for FaultWriter<'_> {
    fn append(&mut self, chunk: &[u8]) -> Result<()> {
        match self
            .shared
            .observe(OpKind::Append, &self.key, Some(self.written))?
        {
            None => {
                let w = self.inner.as_mut().ok_or_else(|| {
                    Error::Injected(format!("writer for `{}` already abandoned", self.key))
                })?;
                w.append(chunk)?;
                self.written += chunk.len() as u64;
                Ok(())
            }
            Some(FaultKind::Crash) => {
                let err = self.shared.trip(FaultKind::Crash, OpKind::Append, &self.key);
                self.abandon();
                Err(err)
            }
            Some(kind) => Err(self.shared.trip(kind, OpKind::Append, &self.key)),
        }
    }

    fn written(&self) -> u64 {
        self.written
    }

    fn commit(mut self: Box<Self>) -> Result<()> {
        match self.shared.observe(OpKind::Commit, &self.key, None)? {
            None => match self.inner.take() {
                Some(w) => w.commit(),
                None => Err(Error::Injected(format!(
                    "writer for `{}` already abandoned",
                    self.key
                ))),
            },
            Some(FaultKind::Crash) => {
                let err = self.shared.trip(FaultKind::Crash, OpKind::Commit, &self.key);
                self.abandon();
                Err(err)
            }
            Some(kind) => {
                // an injected (non-crash) commit failure publishes nothing
                // and must leave no orphans: drop the staging cleanly
                let err = self.shared.trip(kind, OpKind::Commit, &self.key);
                if let Some(w) = self.inner.take() {
                    if let Err(e) = w.abort() {
                        crate::log_warn!(
                            "staging cleanup after injected commit fault on `{}` failed: {e}",
                            self.key
                        );
                    }
                }
                Err(err)
            }
        }
    }

    fn abort(mut self: Box<Self>) -> Result<()> {
        match self.shared.observe(OpKind::Abort, &self.key, None)? {
            None => match self.inner.take() {
                Some(w) => w.abort(),
                None => Ok(()),
            },
            Some(FaultKind::Crash) => {
                let err = self.shared.trip(FaultKind::Crash, OpKind::Abort, &self.key);
                self.abandon();
                Err(err)
            }
            Some(kind) => {
                let err = self.shared.trip(kind, OpKind::Abort, &self.key);
                if let Some(w) = self.inner.take() {
                    // still clean up: abort is best-effort
                    if let Err(e) = w.abort() {
                        crate::log_warn!(
                            "staging cleanup after injected abort fault on `{}` failed: {e}",
                            self.key
                        );
                    }
                }
                Err(err)
            }
        }
    }
}

impl Drop for FaultWriter<'_> {
    fn drop(&mut self) {
        // dropping an un-crashed faulty writer behaves like dropping the
        // inner one (cleanup runs); after a crash `inner` is already gone
        self.inner = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::memstore::MemStore;

    fn mem() -> MemStore {
        MemStore::new(u64::MAX, "lru").unwrap()
    }

    #[test]
    fn empty_plan_is_transparent() {
        let m = mem();
        let f = FaultStore::new(&m, FaultPlan::new());
        f.write("k", b"hello").unwrap();
        assert_eq!(f.read("k").unwrap(), b"hello");
        assert_eq!(f.stat("k").unwrap().size, 5);
        assert_eq!(f.stats(), FaultStats::default());
        assert!(!f.crashed());
    }

    #[test]
    fn fail_at_fires_once_on_the_nth_op() {
        let m = mem();
        let f = FaultStore::new(&m, FaultPlan::fail_at(OpKind::Create, 1));
        f.write("a", b"1").unwrap(); // create #0: passes
        let err = f.write("b", b"2").unwrap_err(); // create #1: fires
        assert!(matches!(err, Error::Injected(_)), "{err}");
        f.write("c", b"3").unwrap(); // trigger spent
        assert_eq!(f.stats().injected_errors, 1);
        assert!(!m.contains("b"), "failed create published nothing");
    }

    #[test]
    fn key_pattern_filter_only_hits_matching_keys() {
        let m = mem();
        let plan = FaultPlan::new().with(Trigger {
            op: OpKind::Create,
            after: 0,
            key_pattern: Some("hot".into()),
            min_offset: None,
            kind: FaultKind::Error,
        });
        let f = FaultStore::new(&m, plan);
        f.write("cold", &[0u8; 64]).unwrap(); // key filter: no match
        f.write("lukewarm", &[0u8; 8]).unwrap();
        let err = f.write("hot/x", &[1u8; 8]).unwrap_err();
        assert!(matches!(err, Error::Injected(_)), "{err}");
        f.write("hot/x", &[1u8; 8]).unwrap(); // trigger spent
    }

    #[test]
    fn offset_trigger_fires_at_threshold() {
        let m = mem();
        let plan = FaultPlan::new().with(Trigger {
            op: OpKind::Append,
            after: 0,
            key_pattern: None,
            min_offset: Some(10),
            kind: FaultKind::Error,
        });
        let f = FaultStore::new(&m, plan);
        let mut w = f.create("k").unwrap();
        w.append(&[1u8; 8]).unwrap(); // offset 0
        w.append(&[1u8; 8]).unwrap(); // offset 8
        let err = w.append(&[1u8; 8]).unwrap_err(); // offset 16 ≥ 10: fires
        assert!(matches!(err, Error::Injected(_)));
        w.abort().unwrap();
        assert!(!m.contains("k"));
    }

    #[test]
    fn short_reads_still_reassemble() {
        let m = mem();
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        m.write("k", &data).unwrap();
        let plan = FaultPlan::new()
            .with(Trigger {
                op: OpKind::ReadAt,
                after: 0,
                key_pattern: None,
                min_offset: None,
                kind: FaultKind::ShortRead,
            })
            .with(Trigger {
                op: OpKind::ReadAt,
                after: 1,
                key_pattern: None,
                min_offset: None,
                kind: FaultKind::ShortRead,
            });
        let f = FaultStore::new(&m, plan);
        // the default `read` adapter loops read_at until done
        assert_eq!(f.read("k").unwrap(), data);
        assert_eq!(f.stats().short_reads, 2);
    }

    #[test]
    fn corrupt_read_flips_served_bytes() {
        let m = mem();
        m.write("k", &[7u8; 100]).unwrap();
        let f = FaultStore::new(&m, FaultPlan::new().with(Trigger {
            op: OpKind::ReadAt,
            after: 0,
            key_pattern: None,
            min_offset: None,
            kind: FaultKind::CorruptRead,
        }));
        let got = f.read("k").unwrap();
        assert_ne!(got, vec![7u8; 100], "corruption must be visible");
        assert_eq!(got[0], 7 ^ 0xFF);
        assert_eq!(&got[1..], &[7u8; 99][..]);
        assert_eq!(f.stats().corruptions, 1);
    }

    #[test]
    fn crash_poisons_every_subsequent_op() {
        let m = mem();
        m.write("old", b"survivor").unwrap();
        let f = FaultStore::new(&m, FaultPlan::crash_at(OpKind::Commit, 0));
        let mut w = f.create("new").unwrap();
        w.append(b"doomed").unwrap();
        let err = w.commit().unwrap_err();
        assert!(matches!(err, Error::Injected(_)), "{err}");
        assert!(f.crashed());
        assert_eq!(f.stats().crashes, 1);
        // everything after the crash is refused
        assert!(matches!(f.stat("old"), Err(Error::Injected(_))));
        assert!(matches!(f.open("old"), Err(Error::Injected(_))));
        assert!(matches!(f.create("x"), Err(Error::Injected(_))));
        assert!(matches!(f.delete("old"), Err(Error::Injected(_))));
        assert!(f.list("").is_empty(), "a dead store lists nothing");
        // the real store is untouched by the wrapper's death
        assert_eq!(m.read("old").unwrap(), b"survivor");
        assert!(!m.contains("new"));
    }

    #[test]
    fn injected_commit_error_leaves_no_partial_state() {
        let m = mem();
        let f = FaultStore::new(&m, FaultPlan::fail_at(OpKind::Commit, 0));
        let mut w = f.create("k").unwrap();
        w.append(b"data").unwrap();
        assert!(matches!(w.commit(), Err(Error::Injected(_))));
        assert!(!m.contains("k"), "failed commit published nothing");
        // and the store stays fully usable
        f.write("k", b"retry").unwrap();
        assert_eq!(f.read("k").unwrap(), b"retry");
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        for seed in 0..32u64 {
            let a = FaultPlan::seeded(seed);
            let b = FaultPlan::seeded(seed);
            assert_eq!(a.triggers.len(), 1);
            assert_eq!(a.triggers[0].op, b.triggers[0].op);
            assert_eq!(a.triggers[0].kind, b.triggers[0].kind);
            assert_eq!(a.triggers[0].after, b.triggers[0].after);
        }
    }

    #[test]
    fn parse_round_trips_the_grammar() {
        let p = FaultPlan::parse("op=commit,kind=crash,after=2,key=part,offset=4096").unwrap();
        assert_eq!(p.triggers.len(), 1);
        let t = &p.triggers[0];
        assert_eq!(t.op, OpKind::Commit);
        assert_eq!(t.kind, FaultKind::Crash);
        assert_eq!(t.after, 2);
        assert_eq!(t.key_pattern.as_deref(), Some("part"));
        assert_eq!(t.min_offset, Some(4096));

        let p = FaultPlan::parse("op=read,kind=short; op=append").unwrap();
        assert_eq!(p.triggers.len(), 2);
        assert_eq!(p.triggers[0].kind, FaultKind::ShortRead);
        assert_eq!(p.triggers[1].kind, FaultKind::Error);

        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("kind=crash").is_err(), "op is required");
        assert!(FaultPlan::parse("op=frobnicate").is_err());
        assert!(FaultPlan::parse("op=read,nope=1").is_err());
    }

    #[test]
    fn dropping_uncrashed_faulty_writer_cleans_up() {
        let m = mem();
        {
            let f = FaultStore::new(&m, FaultPlan::new());
            let mut w = f.create("gone").unwrap();
            w.append(&[1u8; 50]).unwrap();
            // dropped uncommitted: inner cleanup must run
        }
        assert!(!m.contains("gone"));
        assert_eq!(m.used(), 0);
    }
}
