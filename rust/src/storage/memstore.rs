//! The in-memory storage tier (the paper's Tachyon).
//!
//! A capacity-bounded block store: values are `Arc<[u8]>` so reads are
//! zero-copy, eviction runs under the same short critical section as the
//! insert that overflowed, and hit/miss/eviction counters feed the
//! Figure-6/ablation benches.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::storage::eviction::{self, EvictionPolicy};

/// Snapshot of the tier's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub inserts: u64,
    pub used: u64,
    pub capacity: u64,
}

impl MemStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Inner {
    map: HashMap<String, Arc<[u8]>>,
    policy: Box<dyn EvictionPolicy>,
    used: u64,
}

/// Capacity-bounded in-memory block store with pluggable eviction.
pub struct MemStore {
    inner: Mutex<Inner>,
    capacity: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inserts: AtomicU64,
}

impl MemStore {
    /// `capacity` bytes, `policy` = `"lru"` | `"lfu"`.
    pub fn new(capacity: u64, policy: &str) -> Result<Self> {
        let policy = eviction::by_name(policy)
            .ok_or_else(|| Error::Config(format!("unknown eviction policy `{policy}`")))?;
        Ok(Self {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                policy,
                used: 0,
            }),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
        })
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Insert a block, evicting per policy until it fits. Returns the
    /// evicted `(key, bytes)` pairs so the caller (the two-level store)
    /// can spill un-persisted victims to the PFS before the bytes are
    /// forgotten.
    ///
    /// A block larger than the whole tier is rejected with
    /// [`Error::OverCapacity`] — the paper's answer to that case is the
    /// PFS tier, not the memory tier.
    pub fn put(&self, key: &str, data: Arc<[u8]>) -> Result<Vec<(String, Arc<[u8]>)>> {
        let len = data.len() as u64;
        if len > self.capacity {
            return Err(Error::OverCapacity {
                need: len,
                capacity: self.capacity,
            });
        }
        let mut g = self.inner.lock().unwrap();
        let mut evicted = Vec::new();
        // replace-in-place frees the old bytes first
        if let Some(old) = g.map.remove(key) {
            g.used -= old.len() as u64;
            g.policy.on_remove(key);
        }
        while g.used + len > self.capacity {
            let victim = g
                .policy
                .victim()
                .expect("used > 0 implies a tracked victim");
            let bytes = g.map.remove(&victim).expect("policy tracks live keys");
            g.used -= bytes.len() as u64;
            g.policy.on_remove(&victim);
            self.evictions.fetch_add(1, Ordering::Relaxed);
            evicted.push((victim, bytes));
        }
        g.map.insert(key.to_string(), data);
        g.used += len;
        g.policy.on_insert(key);
        self.inserts.fetch_add(1, Ordering::Relaxed);
        Ok(evicted)
    }

    /// Fetch a block (recording a hit or miss and a policy access).
    pub fn get(&self, key: &str) -> Option<Arc<[u8]>> {
        let mut g = self.inner.lock().unwrap();
        match g.map.get(key).cloned() {
            Some(v) => {
                g.policy.on_access(key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching eviction state or counters (used by tests and
    /// the checkpointer).
    pub fn peek(&self, key: &str) -> Option<Arc<[u8]>> {
        self.inner.lock().unwrap().map.get(key).cloned()
    }

    /// Whether the key is currently resident.
    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().map.contains_key(key)
    }

    /// Remove a block if present; returns whether it was.
    pub fn remove(&self, key: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        match g.map.remove(key) {
            Some(bytes) => {
                g.used -= bytes.len() as u64;
                g.policy.on_remove(key);
                true
            }
            None => false,
        }
    }

    /// Resident keys with `prefix`, sorted.
    pub fn list(&self, prefix: &str) -> Vec<String> {
        let g = self.inner.lock().unwrap();
        let mut keys: Vec<String> = g
            .map
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        keys.sort();
        keys
    }

    /// Bytes currently resident.
    pub fn used(&self) -> u64 {
        self.inner.lock().unwrap().used
    }

    pub fn stats(&self) -> MemStats {
        MemStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            used: self.used(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bytes(n: usize, fill: u8) -> Arc<[u8]> {
        vec![fill; n].into()
    }

    #[test]
    fn put_get_roundtrip() {
        let m = MemStore::new(1024, "lru").unwrap();
        m.put("a", bytes(10, 1)).unwrap();
        assert_eq!(&m.get("a").unwrap()[..], &[1u8; 10][..]);
        assert_eq!(m.used(), 10);
        assert!(m.contains("a"));
        assert!(!m.contains("b"));
    }

    #[test]
    fn capacity_eviction_lru_order() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(40, 0)).unwrap();
        m.put("b", bytes(40, 0)).unwrap();
        let _ = m.get("a"); // b becomes LRU
        let evicted = m.put("c", bytes(40, 0)).unwrap();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, "b");
        assert_eq!(evicted[0].1.len(), 40); // victim bytes travel with it
        assert!(m.contains("a") && m.contains("c"));
        assert_eq!(m.used(), 80);
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn oversized_block_rejected() {
        let m = MemStore::new(100, "lru").unwrap();
        let err = m.put("big", bytes(101, 0)).unwrap_err();
        assert!(matches!(err, Error::OverCapacity { .. }));
        assert_eq!(m.used(), 0);
    }

    #[test]
    fn exact_fit_allowed() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("x", bytes(100, 7)).unwrap();
        assert_eq!(m.used(), 100);
        // replacing with same size evicts nothing
        assert!(m.put("x", bytes(100, 8)).unwrap().is_empty());
        assert_eq!(m.get("x").unwrap()[0], 8);
    }

    #[test]
    fn replace_updates_accounting() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("k", bytes(60, 1)).unwrap();
        m.put("k", bytes(20, 2)).unwrap();
        assert_eq!(m.used(), 20);
        assert_eq!(m.get("k").unwrap().len(), 20);
    }

    #[test]
    fn multi_eviction_for_large_insert() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(30, 0)).unwrap();
        m.put("b", bytes(30, 0)).unwrap();
        m.put("c", bytes(30, 0)).unwrap();
        let evicted = m.put("d", bytes(90, 0)).unwrap();
        assert_eq!(evicted.len(), 3);
        assert_eq!(m.used(), 90);
    }

    #[test]
    fn hit_miss_counters() {
        let m = MemStore::new(100, "lfu").unwrap();
        m.put("a", bytes(10, 0)).unwrap();
        let _ = m.get("a");
        let _ = m.get("a");
        let _ = m.get("nope");
        let s = m.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn lfu_keeps_hot_blocks() {
        let m = MemStore::new(100, "lfu").unwrap();
        m.put("hot", bytes(50, 0)).unwrap();
        for _ in 0..10 {
            let _ = m.get("hot");
        }
        m.put("cold", bytes(50, 0)).unwrap();
        let evicted = m.put("new", bytes(50, 0)).unwrap();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, "cold");
        assert!(m.contains("hot"));
    }

    #[test]
    fn peek_does_not_count() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(10, 0)).unwrap();
        let _ = m.peek("a");
        let s = m.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
    }

    #[test]
    fn remove_frees_space() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(70, 0)).unwrap();
        assert!(m.remove("a"));
        assert!(!m.remove("a"));
        assert_eq!(m.used(), 0);
        m.put("b", bytes(100, 0)).unwrap(); // fits again
    }

    #[test]
    fn list_filters_and_sorts() {
        let m = MemStore::new(1000, "lru").unwrap();
        for k in ["x#2", "x#0", "y#0", "x#1"] {
            m.put(k, bytes(1, 0)).unwrap();
        }
        assert_eq!(m.list("x#"), vec!["x#0", "x#1", "x#2"]);
        assert_eq!(m.list("z"), Vec::<String>::new());
    }

    #[test]
    fn concurrent_puts_respect_capacity() {
        let m = Arc::new(MemStore::new(1000, "lru").unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    m.put(&format!("t{t}-{i}"), bytes(64, t as u8)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(m.used() <= 1000, "used={} cap=1000", m.used());
    }
}
