//! The in-memory storage tier (the paper's Tachyon).
//!
//! A capacity-bounded block store: values are `Arc<[u8]>` so reads are
//! zero-copy, and hit/miss/eviction counters feed the Figure-6/ablation
//! benches.
//!
//! ## Concurrency: lock striping + a global capacity accountant
//!
//! The tier is sharded into `N` lock-striped shards keyed by a hash of the
//! block key: each shard owns its slice of the map and its own eviction
//! policy state, so concurrent clients touching different blocks never
//! contend on one global mutex (the paper's aggregate-throughput argument
//! needs the memory tier to scale with client count, §4).
//!
//! Capacity is accounted **globally** by a single atomic: a `put` admits
//! its bytes only after a successful compare-and-swap reservation against
//! the accountant, evicting victims shard-by-shard until the reservation
//! fits. Invariants:
//!
//! - `used ≤ capacity` at all times (reservations are CAS-guarded; bytes
//!   are never admitted above the limit, even mid-`put`),
//! - at most **one shard lock** is ever held by a thread (eviction walks
//!   shards one at a time, starting at the inserting key's home shard), so
//!   there is no lock order to violate and no deadlock,
//! - eviction victims leave `put` with their bytes attached, exactly as in
//!   the single-lock design, so the two-level store can spill dirty
//!   victims to the PFS before the bytes are forgotten.
//!
//! [`MemStore::new`] builds a single shard — the deterministic legacy
//! behaviour (global LRU/LFU order) that the eviction-order unit tests and
//! the fig1 baseline measure. [`MemStore::with_shards`] builds the striped
//! version; [`crate::storage::tls::TlsConfig::mem_shards`] selects the
//! count for the two-level store.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::storage::eviction::{self, EvictionPolicy};
use crate::storage::{copy_clamped, ObjectMeta, ObjectReader, ObjectStore, ObjectWriter};

/// Snapshot of the tier's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    /// Reads served from the tier.
    pub hits: u64,
    /// Reads that fell through.
    pub misses: u64,
    /// Victims evicted to fit reservations.
    pub evictions: u64,
    /// Blocks admitted.
    pub inserts: u64,
    /// Bytes currently admitted.
    pub used: u64,
    /// Byte capacity.
    pub capacity: u64,
}

impl MemStats {
    /// Fraction of reads served from the tier (0 when no reads).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One lock stripe: its slice of the key space plus private eviction state.
struct Shard {
    map: HashMap<String, Arc<[u8]>>,
    policy: Box<dyn EvictionPolicy>,
}

/// Capacity-bounded in-memory block store with pluggable eviction and
/// configurable lock striping.
pub struct MemStore {
    shards: Vec<Mutex<Shard>>,
    capacity: u64,
    /// The global capacity accountant: bytes admitted (reserved or
    /// resident). Only ever raised through a CAS that proves
    /// `used + len ≤ capacity`.
    used: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inserts: AtomicU64,
}

impl MemStore {
    /// `capacity` bytes, `policy` = `"lru"` | `"lfu"`; a single shard
    /// (deterministic global eviction order — the pre-striping behaviour
    /// and the fig1 baseline).
    pub fn new(capacity: u64, policy: &str) -> Result<Self> {
        Self::with_shards(capacity, policy, 1)
    }

    /// As [`MemStore::new`] but striped over `shards` locks. Eviction
    /// order is deterministic *within* a shard; across shards it depends
    /// on key placement.
    pub fn with_shards(capacity: u64, policy: &str, shards: usize) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Config("mem shards must be > 0".into()));
        }
        let mut v = Vec::with_capacity(shards);
        for _ in 0..shards {
            let policy = eviction::by_name(policy)
                .ok_or_else(|| Error::Config(format!("unknown eviction policy `{policy}`")))?;
            v.push(Mutex::new(Shard {
                map: HashMap::new(),
                policy,
            }));
        }
        Ok(Self {
            shards: v,
            capacity,
            used: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
        })
    }

    /// The configured byte capacity.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Number of lock stripes.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// FNV-1a over the key selects the home shard.
    fn shard_of(&self, key: &str) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        (crate::util::bytes::fnv1a(key.as_bytes()) % n as u64) as usize
    }

    /// Evict victims until `need` extra bytes fit under `capacity`,
    /// visiting shards round-robin from `home` and holding one shard lock
    /// at a time. Returns whether any victim was evicted this call.
    fn evict_for(
        &self,
        home: usize,
        need: u64,
        evicted: &mut Vec<(String, Arc<[u8]>)>,
    ) -> bool {
        let n = self.shards.len();
        let mut progress = false;
        for off in 0..n {
            let mut g = self.shards[(home + off) % n].lock().unwrap();
            while self.used.load(Ordering::SeqCst).saturating_add(need) > self.capacity {
                let Some(victim) = g.policy.victim() else { break };
                // lint:allow(no-panic): the policy and map are updated in
                // lockstep under this shard's lock, so a victim the policy
                // names is always present in the map
                let bytes = g.map.remove(&victim).expect("policy tracks live keys");
                self.used.fetch_sub(bytes.len() as u64, Ordering::SeqCst);
                g.policy.on_remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                evicted.push((victim, bytes));
                progress = true;
            }
            drop(g);
            if self.used.load(Ordering::SeqCst).saturating_add(need) <= self.capacity {
                return true;
            }
        }
        progress
    }

    /// Insert a block, evicting per policy until it fits. Returns the
    /// evicted `(key, bytes)` pairs so the caller (the two-level store)
    /// can spill un-persisted victims to the PFS before the bytes are
    /// forgotten.
    ///
    /// A block larger than the whole tier is rejected with
    /// [`Error::OverCapacity`] — the paper's answer to that case is the
    /// PFS tier, not the memory tier.
    ///
    /// Overwrite visibility: re-`put`ting a *resident* key frees the old
    /// bytes before reserving the new ones, so a concurrent `get` of that
    /// key can miss inside the replace window (it never observes torn
    /// bytes — only old value, new value, or a miss). The storage contract
    /// is write-once-read-many ([`crate::storage::ObjectStore`]); callers
    /// racing reads against overwrites of the same key are outside it.
    pub fn put(&self, key: &str, data: Arc<[u8]>) -> Result<Vec<(String, Arc<[u8]>)>> {
        let len = data.len() as u64;
        if len > self.capacity {
            return Err(Error::OverCapacity {
                need: len,
                capacity: self.capacity,
            });
        }
        let home = self.shard_of(key);

        // Replace-in-place frees the old bytes before the reservation, so
        // re-writing a key never evicts on its own account.
        {
            let mut g = self.shards[home].lock().unwrap();
            if let Some(old) = g.map.remove(key) {
                self.used.fetch_sub(old.len() as u64, Ordering::SeqCst);
                g.policy.on_remove(key);
            }
        }

        // Reserve space against the global accountant. The CAS only
        // succeeds while the result stays ≤ capacity, so the invariant
        // holds at every instant, not just between puts.
        let mut evicted = Vec::new();
        loop {
            let cur = self.used.load(Ordering::SeqCst);
            let new = cur.saturating_add(len);
            if new <= self.capacity {
                if self
                    .used
                    .compare_exchange(cur, new, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    break;
                }
                continue; // raced another reservation; re-read
            }
            if !self.evict_for(home, len, &mut evicted) {
                // Nothing evictable: another thread holds a reservation it
                // has not yet published. It will publish without blocking
                // on us, so yield and retry.
                std::thread::yield_now();
            }
        }

        // Publish under the home shard lock.
        let mut g = self.shards[home].lock().unwrap();
        if let Some(old) = g.map.insert(key.to_string(), data) {
            // Another thread published the same key between our removal
            // and now; treat it as the replace above.
            self.used.fetch_sub(old.len() as u64, Ordering::SeqCst);
            g.policy.on_remove(key);
        }
        g.policy.on_insert(key);
        drop(g);
        self.inserts.fetch_add(1, Ordering::Relaxed);
        Ok(evicted)
    }

    /// Fetch a block (recording a hit or miss and a policy access).
    pub fn get(&self, key: &str) -> Option<Arc<[u8]>> {
        let mut g = self.shards[self.shard_of(key)].lock().unwrap();
        match g.map.get(key).cloned() {
            Some(v) => {
                g.policy.on_access(key);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Peek without touching eviction state or counters (used by tests and
    /// the checkpointer).
    pub fn peek(&self, key: &str) -> Option<Arc<[u8]>> {
        self.shards[self.shard_of(key)]
            .lock()
            .unwrap()
            .map
            .get(key)
            .cloned()
    }

    /// Whether the key is currently resident.
    pub fn contains(&self, key: &str) -> bool {
        self.shards[self.shard_of(key)]
            .lock()
            .unwrap()
            .map
            .contains_key(key)
    }

    /// Remove a block if present; returns whether it was.
    pub fn remove(&self, key: &str) -> bool {
        let mut g = self.shards[self.shard_of(key)].lock().unwrap();
        match g.map.remove(key) {
            Some(bytes) => {
                self.used.fetch_sub(bytes.len() as u64, Ordering::SeqCst);
                g.policy.on_remove(key);
                true
            }
            None => false,
        }
    }

    /// Resident keys with `prefix`, sorted (shards are visited one at a
    /// time; the result is a point-in-time union, not an atomic snapshot).
    pub fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys = Vec::new();
        for shard in &self.shards {
            let g = shard.lock().unwrap();
            keys.extend(g.map.keys().filter(|k| k.starts_with(prefix)).cloned());
        }
        keys.sort();
        keys
    }

    /// Bytes currently admitted (resident plus in-flight reservations).
    pub fn used(&self) -> u64 {
        self.used.load(Ordering::SeqCst)
    }

    /// Snapshot of the tier's counters.
    pub fn stats(&self) -> MemStats {
        MemStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            used: self.used(),
            capacity: self.capacity,
        }
    }
}

/// Zero-copy reader over one memory-tier value: [`ObjectStore::open`]
/// clones the `Arc<[u8]>` once (under the home shard lock), after which every
/// `read_at` copies straight from the shared bytes — **no shard lock is
/// held during `read_at`**, and the snapshot stays readable even if the
/// key is concurrently overwritten, evicted, or removed.
pub struct MemReader {
    data: Arc<[u8]>,
}

impl MemReader {
    /// The pinned value, for callers that can consume `Arc<[u8]>` directly
    /// (the truly zero-copy path — no bytes move at all).
    pub fn as_arc(&self) -> &Arc<[u8]> {
        &self.data
    }
}

impl ObjectReader for MemReader {
    fn len(&self) -> u64 {
        self.data.len() as u64
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        Ok(copy_clamped(&self.data, offset, buf))
    }
}

/// Streaming writer into the memory tier: chunks accumulate in a private
/// buffer and publish atomically as one `put` on commit (readers of the
/// key see the old value or a miss until then, never a prefix).
pub struct MemWriter<'a> {
    store: &'a MemStore,
    key: String,
    buf: Vec<u8>,
}

impl ObjectWriter for MemWriter<'_> {
    fn append(&mut self, chunk: &[u8]) -> Result<()> {
        self.buf.extend_from_slice(chunk);
        Ok(())
    }

    fn written(&self) -> u64 {
        self.buf.len() as u64
    }

    fn commit(mut self: Box<Self>) -> Result<()> {
        let data: Arc<[u8]> = std::mem::take(&mut self.buf).into();
        // standalone MemStore drops eviction victims (no lower tier)
        self.store.put(&self.key, data)?;
        Ok(())
    }

    fn abort(self: Box<Self>) -> Result<()> {
        Ok(()) // nothing was published; the buffer just drops
    }
}

impl crate::storage::Recover for MemStore {
    /// The memory tier is volatile by contract (the paper's Tachyon): a
    /// restarted store begins empty, so there is never debris to repair —
    /// recovery is a no-op that always reports clean.
    fn recover(&self) -> Result<crate::storage::RecoveryReport> {
        Ok(crate::storage::RecoveryReport::default())
    }
}

impl ObjectStore for MemStore {
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
        let data = self
            .get(key)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        Ok(Box::new(MemReader { data }))
    }

    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
        Ok(Box::new(MemWriter {
            store: self,
            key: key.to_string(),
            buf: Vec::new(),
        }))
    }

    fn stat(&self, key: &str) -> Result<ObjectMeta> {
        // peek: stat must not skew the hit/miss counters or eviction order
        let size = self
            .peek(key)
            .map(|b| b.len() as u64)
            .ok_or_else(|| Error::NotFound(key.to_string()))?;
        Ok(ObjectMeta {
            key: key.to_string(),
            size,
        })
    }

    fn delete(&self, key: &str) -> Result<()> {
        self.remove(key);
        Ok(())
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        MemStore::list(self, prefix)
    }

    fn kind(&self) -> &'static str {
        "mem"
    }

    // whole-object fast paths over the same Arc values
    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        self.put(key, data.to_vec().into())?;
        Ok(())
    }

    fn read(&self, key: &str) -> Result<Vec<u8>> {
        self.get(key)
            .map(|b| b.to_vec())
            .ok_or_else(|| Error::NotFound(key.to_string()))
    }

    fn exists(&self, key: &str) -> bool {
        self.contains(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bytes(n: usize, fill: u8) -> Arc<[u8]> {
        vec![fill; n].into()
    }

    #[test]
    fn put_get_roundtrip() {
        let m = MemStore::new(1024, "lru").unwrap();
        m.put("a", bytes(10, 1)).unwrap();
        assert_eq!(&m.get("a").unwrap()[..], &[1u8; 10][..]);
        assert_eq!(m.used(), 10);
        assert!(m.contains("a"));
        assert!(!m.contains("b"));
    }

    #[test]
    fn capacity_eviction_lru_order() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(40, 0)).unwrap();
        m.put("b", bytes(40, 0)).unwrap();
        let _ = m.get("a"); // b becomes LRU
        let evicted = m.put("c", bytes(40, 0)).unwrap();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, "b");
        assert_eq!(evicted[0].1.len(), 40); // victim bytes travel with it
        assert!(m.contains("a") && m.contains("c"));
        assert_eq!(m.used(), 80);
        assert_eq!(m.stats().evictions, 1);
    }

    #[test]
    fn oversized_block_rejected() {
        let m = MemStore::new(100, "lru").unwrap();
        let err = m.put("big", bytes(101, 0)).unwrap_err();
        assert!(matches!(err, Error::OverCapacity { .. }));
        assert_eq!(m.used(), 0);
    }

    #[test]
    fn exact_fit_allowed() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("x", bytes(100, 7)).unwrap();
        assert_eq!(m.used(), 100);
        // replacing with same size evicts nothing
        assert!(m.put("x", bytes(100, 8)).unwrap().is_empty());
        assert_eq!(m.get("x").unwrap()[0], 8);
    }

    #[test]
    fn replace_updates_accounting() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("k", bytes(60, 1)).unwrap();
        m.put("k", bytes(20, 2)).unwrap();
        assert_eq!(m.used(), 20);
        assert_eq!(m.get("k").unwrap().len(), 20);
    }

    #[test]
    fn multi_eviction_for_large_insert() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(30, 0)).unwrap();
        m.put("b", bytes(30, 0)).unwrap();
        m.put("c", bytes(30, 0)).unwrap();
        let evicted = m.put("d", bytes(90, 0)).unwrap();
        assert_eq!(evicted.len(), 3);
        assert_eq!(m.used(), 90);
    }

    #[test]
    fn hit_miss_counters() {
        let m = MemStore::new(100, "lfu").unwrap();
        m.put("a", bytes(10, 0)).unwrap();
        let _ = m.get("a");
        let _ = m.get("a");
        let _ = m.get("nope");
        let s = m.stats();
        assert_eq!((s.hits, s.misses), (2, 1));
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn lfu_keeps_hot_blocks() {
        let m = MemStore::new(100, "lfu").unwrap();
        m.put("hot", bytes(50, 0)).unwrap();
        for _ in 0..10 {
            let _ = m.get("hot");
        }
        m.put("cold", bytes(50, 0)).unwrap();
        let evicted = m.put("new", bytes(50, 0)).unwrap();
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0].0, "cold");
        assert!(m.contains("hot"));
    }

    #[test]
    fn peek_does_not_count() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(10, 0)).unwrap();
        let _ = m.peek("a");
        let s = m.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
    }

    #[test]
    fn remove_frees_space() {
        let m = MemStore::new(100, "lru").unwrap();
        m.put("a", bytes(70, 0)).unwrap();
        assert!(m.remove("a"));
        assert!(!m.remove("a"));
        assert_eq!(m.used(), 0);
        m.put("b", bytes(100, 0)).unwrap(); // fits again
    }

    #[test]
    fn list_filters_and_sorts() {
        let m = MemStore::new(1000, "lru").unwrap();
        for k in ["x#2", "x#0", "y#0", "x#1"] {
            m.put(k, bytes(1, 0)).unwrap();
        }
        assert_eq!(m.list("x#"), vec!["x#0", "x#1", "x#2"]);
        assert_eq!(m.list("z"), Vec::<String>::new());
    }

    #[test]
    fn concurrent_puts_respect_capacity() {
        let m = Arc::new(MemStore::new(1000, "lru").unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    m.put(&format!("t{t}-{i}"), bytes(64, t as u8)).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(m.used() <= 1000, "used={} cap=1000", m.used());
    }

    // -- striped-shard behaviour ------------------------------------------

    #[test]
    fn sharded_roundtrip_and_accounting() {
        let m = MemStore::with_shards(1 << 20, "lru", 8).unwrap();
        assert_eq!(m.shards(), 8);
        let mut total = 0u64;
        for i in 0..64 {
            m.put(&format!("obj#{i}"), bytes(100 + i, i as u8)).unwrap();
            total += 100 + i as u64;
        }
        assert_eq!(m.used(), total);
        for i in 0..64 {
            assert_eq!(m.get(&format!("obj#{i}")).unwrap().len(), 100 + i);
        }
        assert_eq!(m.list("obj#").len(), 64);
        assert!(m.remove("obj#0"));
        assert_eq!(m.used(), total - 100);
    }

    #[test]
    fn sharded_zero_shards_rejected() {
        assert!(MemStore::with_shards(100, "lru", 0).is_err());
        assert!(MemStore::with_shards(100, "nope", 4).is_err());
    }

    #[test]
    fn sharded_eviction_crosses_shards() {
        // With many shards and a capacity for only 2 blocks, inserting a
        // third must evict from *some* shard, wherever the victims live.
        let m = MemStore::with_shards(100, "lru", 16).unwrap();
        m.put("a", bytes(40, 0)).unwrap();
        m.put("b", bytes(40, 0)).unwrap();
        let evicted = m.put("c", bytes(40, 0)).unwrap();
        assert_eq!(evicted.len(), 1, "one 40-byte victim frees enough");
        assert_eq!(m.used(), 80);
        assert!(m.contains("c"), "the new key is never its own victim");
    }

    #[test]
    fn sharded_concurrent_puts_never_exceed_capacity() {
        let m = Arc::new(MemStore::with_shards(10_000, "lru", 8).unwrap());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        // sampler: the accountant invariant must hold at every instant
        let sampler = {
            let m = Arc::clone(&m);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut max_seen = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    max_seen = max_seen.max(m.used());
                    std::thread::yield_now();
                }
                max_seen
            })
        };
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for i in 0..200 {
                    m.put(&format!("t{t}/k{i}"), bytes(128, t as u8)).unwrap();
                    let _ = m.get(&format!("t{t}/k{}", i / 2));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        let max_seen = sampler.join().unwrap();
        assert!(max_seen <= 10_000, "observed used {max_seen} > capacity");
        assert!(m.used() <= 10_000);
        assert!(m.stats().evictions > 0, "pressure must have evicted");
    }

    // -- v2 handle surface ------------------------------------------------

    #[test]
    fn reader_is_zero_copy_and_pins_its_snapshot() {
        let m = MemStore::new(1024, "lru").unwrap();
        ObjectStore::write(&m, "k", &[7u8; 64]).unwrap();
        let hits_before = m.stats().hits;
        let r = ObjectStore::open(&m, "k").unwrap();
        assert_eq!(m.stats().hits, hits_before + 1, "open records one access");
        assert_eq!(r.len(), 64);

        // read_at touches no shard lock and no counters
        let mut buf = [0u8; 16];
        assert_eq!(r.read_at(0, &mut buf).unwrap(), 16);
        assert_eq!(buf, [7u8; 16]);
        assert_eq!(m.stats().hits, hits_before + 1);

        // the snapshot survives removal and overwrite: the Arc is pinned
        m.remove("k");
        ObjectStore::write(&m, "k", &[9u8; 8]).unwrap();
        assert_eq!(r.read_at(60, &mut buf).unwrap(), 4, "EOF clamp");
        assert_eq!(&buf[..4], &[7u8; 4]);
        assert_eq!(r.read_at(64, &mut buf).unwrap(), 0, "at EOF");
    }

    #[test]
    fn writer_publishes_atomically_on_commit() {
        let m = MemStore::new(4096, "lru").unwrap();
        let mut w = ObjectStore::create(&m, "obj").unwrap();
        w.append(b"hello ").unwrap();
        assert!(!ObjectStore::exists(&m, "obj"), "invisible before commit");
        w.append(b"world").unwrap();
        assert_eq!(w.written(), 11);
        w.commit().unwrap();
        assert_eq!(ObjectStore::read(&m, "obj").unwrap(), b"hello world");
        assert_eq!(ObjectStore::stat(&m, "obj").unwrap().size, 11);
    }

    #[test]
    fn writer_abort_leaves_nothing() {
        let m = MemStore::new(4096, "lru").unwrap();
        let w = {
            let mut w = ObjectStore::create(&m, "gone").unwrap();
            w.append(&[1u8; 100]).unwrap();
            w
        };
        w.abort().unwrap();
        assert!(!ObjectStore::exists(&m, "gone"));
        assert_eq!(m.used(), 0);
        assert!(ObjectStore::stat(&m, "gone").is_err());
    }

    #[test]
    fn sharded_concurrent_readers_and_writers_agree() {
        let m = Arc::new(MemStore::with_shards(1 << 20, "lfu", 4).unwrap());
        std::thread::scope(|s| {
            for t in 0..4u8 {
                let m = Arc::clone(&m);
                s.spawn(move || {
                    for i in 0..100 {
                        let key = format!("w{t}/{i}");
                        m.put(&key, bytes(64, t)).unwrap();
                        // read-your-writes under striping
                        let back = m.get(&key).expect("own write visible");
                        assert_eq!(back[0], t);
                    }
                });
            }
        });
        assert_eq!(m.list("w").len(), 400);
    }
}
