//! I/O buffer management (§3.2).
//!
//! The paper uses two tuned buffers — 1 MB between the application and the
//! memory tier, 4 MB between the memory tier and the PFS — "selected by
//! performing a series of I/O throughput measurements" (our ablation bench
//! reruns that series). [`BufferPool`] recycles those buffers so the read
//! path allocates nothing in steady state, and [`copy_chunked`] is the
//! shared chunked-transfer loop.

use std::sync::Mutex;

/// A recycling pool of fixed-size byte buffers.
pub struct BufferPool {
    buf_size: usize,
    max_pooled: usize,
    free: Mutex<Vec<Vec<u8>>>,
}

impl BufferPool {
    /// Pool of `buf_size`-byte buffers, retaining at most `max_pooled`
    /// free buffers (excess simply drop).
    pub fn new(buf_size: usize, max_pooled: usize) -> Self {
        Self {
            buf_size,
            max_pooled,
            free: Mutex::new(Vec::new()),
        }
    }

    /// The configured buffer capacity.
    pub fn buf_size(&self) -> usize {
        self.buf_size
    }

    /// Take a zero-length buffer with `buf_size` capacity.
    pub fn take(&self) -> PooledBuf<'_> {
        let buf = self
            .free
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(self.buf_size));
        PooledBuf { pool: self, buf }
    }

    fn give_back(&self, mut buf: Vec<u8>) {
        // A buffer that *grew* past `buf_size` stays useful — truncating
        // its length is free and the extra capacity just means fewer
        // reallocations next time — so keep it. Only a buffer that ended
        // up *below* `buf_size` capacity (shrunk via `shrink_to_fit` or
        // swapped out) is dropped: pooling it would break the "take()
        // yields `buf_size` capacity" contract.
        if buf.capacity() < self.buf_size {
            return;
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_pooled {
            free.push(buf);
        }
    }

    /// Take a buffer *detached* from the pool's lifetime: a plain
    /// `Vec<u8>` for callers that must move it into a `'static` closure
    /// (the prefetch slots of the overlap layer). Pair with
    /// [`recycle`](BufferPool::recycle) to return it; a detached buffer
    /// that is simply dropped is lost to the pool, never leaked.
    pub fn take_detached(&self) -> Vec<u8> {
        self.free
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_else(|| Vec::with_capacity(self.buf_size))
    }

    /// Return a buffer obtained via [`take_detached`](BufferPool::take_detached)
    /// (or any compatible allocation) to the pool. Same retention rules
    /// as the RAII path: grown buffers are kept, under-capacity ones
    /// dropped, retention capped at `max_pooled`.
    pub fn recycle(&self, buf: Vec<u8>) {
        self.give_back(buf);
    }

    /// Currently pooled free buffers (for tests/metrics).
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// RAII handle returning its buffer to the pool on drop.
pub struct PooledBuf<'a> {
    pool: &'a BufferPool,
    buf: Vec<u8>,
}

impl std::ops::Deref for PooledBuf<'_> {
    type Target = Vec<u8>;
    fn deref(&self) -> &Vec<u8> {
        &self.buf
    }
}

impl std::ops::DerefMut for PooledBuf<'_> {
    fn deref_mut(&mut self) -> &mut Vec<u8> {
        &mut self.buf
    }
}

impl Drop for PooledBuf<'_> {
    fn drop(&mut self) {
        self.pool.give_back(std::mem::take(&mut self.buf));
    }
}

/// Copy `src` into `dst` through chunks of `chunk` bytes, invoking
/// `on_chunk(bytes_so_far)` after each chunk — the hook the throughput
/// meters and the simulator's pacing use. Returns bytes copied.
pub fn copy_chunked(
    src: &[u8],
    dst: &mut Vec<u8>,
    chunk: usize,
    mut on_chunk: impl FnMut(usize),
) -> usize {
    debug_assert!(chunk > 0);
    dst.reserve(src.len());
    let mut done = 0;
    for piece in src.chunks(chunk.max(1)) {
        dst.extend_from_slice(piece);
        done += piece.len();
        on_chunk(done);
    }
    done
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_recycles_buffers() {
        let pool = BufferPool::new(1024, 4);
        {
            let mut b = pool.take();
            b.extend_from_slice(&[1, 2, 3]);
            assert!(b.capacity() >= 1024);
        }
        assert_eq!(pool.pooled(), 1);
        {
            let b = pool.take();
            assert!(b.is_empty(), "recycled buffer must be cleared");
        }
        assert_eq!(pool.pooled(), 1);
    }

    #[test]
    fn grown_buffers_are_kept() {
        let pool = BufferPool::new(64, 4);
        {
            let mut b = pool.take();
            // grow well past buf_size: still poolable
            b.resize(1024, 7);
            assert!(b.capacity() >= 1024);
        }
        assert_eq!(pool.pooled(), 1, "a grown buffer must be recycled");
        let b = pool.take();
        assert!(b.is_empty());
        assert!(b.capacity() >= 64, "recycled capacity never below buf_size");
    }

    #[test]
    fn shrunk_buffers_are_dropped() {
        let pool = BufferPool::new(64, 4);
        {
            let mut b = pool.take();
            // swap in an under-sized allocation: must not be pooled
            let small = Vec::with_capacity(8);
            let _old = std::mem::replace(&mut *b, small);
        }
        assert_eq!(pool.pooled(), 0, "a shrunk buffer must not be pooled");
        // the pool still hands out full-size buffers afterwards
        assert!(pool.take().capacity() >= 64);
    }

    #[test]
    fn pool_caps_retention() {
        let pool = BufferPool::new(64, 2);
        let a = pool.take();
        let b = pool.take();
        let c = pool.take();
        drop(a);
        drop(b);
        drop(c);
        assert_eq!(pool.pooled(), 2);
    }

    #[test]
    fn detached_buffers_recycle_through_the_pool() {
        let pool = BufferPool::new(64, 4);
        let mut b = pool.take_detached();
        assert!(b.capacity() >= 64);
        b.extend_from_slice(&[1, 2, 3]);
        pool.recycle(b);
        assert_eq!(pool.pooled(), 1);
        let b2 = pool.take_detached();
        assert!(b2.is_empty(), "recycled detached buffer must be cleared");
        assert_eq!(pool.pooled(), 0);
        // a shrunk detached buffer is refused, like the RAII path
        pool.recycle(Vec::with_capacity(8));
        assert_eq!(pool.pooled(), 0);
    }

    #[test]
    fn copy_chunked_covers_all_bytes() {
        let src: Vec<u8> = (0..=255u8).collect();
        let mut dst = Vec::new();
        let mut calls = Vec::new();
        let n = copy_chunked(&src, &mut dst, 100, |done| calls.push(done));
        assert_eq!(n, 256);
        assert_eq!(dst, src);
        assert_eq!(calls, vec![100, 200, 256]);
    }

    #[test]
    fn copy_chunked_empty_source() {
        let mut dst = Vec::new();
        let n = copy_chunked(&[], &mut dst, 8, |_| panic!("no chunks expected"));
        assert_eq!(n, 0);
        assert!(dst.is_empty());
    }

    #[test]
    fn copy_chunked_chunk_larger_than_source() {
        let mut dst = Vec::new();
        let n = copy_chunked(b"abc", &mut dst, 1 << 20, |d| assert_eq!(d, 3));
        assert_eq!(n, 3);
        assert_eq!(dst, b"abc");
    }
}
