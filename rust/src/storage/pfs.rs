//! The parallel-file-system tier (the paper's OrangeFS).
//!
//! Objects are striped round-robin across `servers` directories — each
//! directory standing in for one data node's RAID volume — with one
//! *datafile* per server per object (exactly OrangeFS's layout: a file is
//! N datafiles, stripe k lives at offset `(k / N) * stripe` of datafile
//! `k % N`). A small metadata file records size/geometry/CRC, playing the
//! metadata-server role.
//!
//! The "Tachyon-OFS plug-in hints" of §3 map to [`Hints`]: per-write
//! stripe-size and server-count overrides.
//!
//! Server I/O is issued in parallel (one task per server via the shared
//! [`ThreadPool`]), which is what gives the tier its aggregate-bandwidth
//! behaviour: a read of one object engages every data node at once. This
//! covers all three access shapes: whole-object writes, whole-object
//! reads, and ranged reads (`read_range` groups the requested stripes per
//! server and fans one task out per involved server — the path the
//! two-level store's block reads ride).

use std::fs;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::storage::block::{checksum, verify_checksum, Crc32};
use crate::storage::layout::{StripeLayout, StripeSegment};
use crate::storage::{
    clamped_len, is_writer_temp, ObjectMeta, ObjectReader, ObjectStore, ObjectWriter, Recover,
    RecoveryReport, SHUFFLE_NS,
};
use crate::util::pool::ThreadPool;

/// Uniquifies in-flight writer temp files (several writers may stream the
/// same key concurrently; last committed meta wins, as with `write`).
static PFS_WRITER_SEQ: AtomicU64 = AtomicU64::new(0);

/// Key prefix under which [`Pfs::recover_pfs`] parks objects whose on-disk
/// state is inconsistent (truncated / mixed-version datafiles, undecodable
/// metadata). Quarantined objects read as `NotFound` under their original
/// key; the bytes are preserved for forensics.
pub const QUARANTINE_NS: &str = ".quarantine/";

/// Remove `path` if it exists; `Ok(true)` when a file was removed,
/// `Ok(false)` when there was nothing to remove, `Err` on a real
/// filesystem failure (the case rollback paths must not swallow).
pub(crate) fn remove_existing(path: &Path) -> Result<bool> {
    match fs::remove_file(path) {
        Ok(()) => Ok(true),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
        Err(e) => Err(Error::io(path, e)),
    }
}

/// Per-write layout overrides (the plug-in "hints" of §3.1).
#[derive(Debug, Clone, Copy, Default)]
pub struct Hints {
    /// Override stripe size for this object.
    pub stripe_size: Option<u64>,
    /// Use only the first `n` servers (e.g. to emulate fewer data nodes).
    pub servers: Option<usize>,
}

/// Counters for the tier.
#[derive(Debug, Clone, Copy, Default)]
pub struct PfsStats {
    /// Bytes striped out to servers.
    pub bytes_written: u64,
    /// Bytes read back from stripes.
    pub bytes_read: u64,
    /// Objects committed.
    pub objects_written: u64,
    /// Read operations served.
    pub reads: u64,
}

/// Striped object store over `servers` directories.
pub struct Pfs {
    meta_dir: PathBuf,
    server_dirs: Vec<PathBuf>,
    default_stripe: u64,
    pool: Arc<ThreadPool>,
    bytes_written: AtomicU64,
    bytes_read: AtomicU64,
    objects_written: AtomicU64,
    reads: AtomicU64,
    /// Verify stripe CRCs on every read (on by default; the ablation bench
    /// measures its cost).
    pub verify_reads: bool,
    /// Coalesce streaming-writer appends until at least this many bytes
    /// are buffered, then stripe them out in one fan-out (`0` =
    /// append-through, the historical behavior). Snapshotted per writer
    /// at `create`; the overlap bench flips it.
    pub append_coalesce: usize,
}

impl Pfs {
    /// Open (creating directories) a PFS rooted at `root` with `servers`
    /// server directories and `stripe` default stripe size.
    pub fn open(root: &Path, servers: usize, stripe: u64) -> Result<Self> {
        Self::open_with_pool(root, servers, stripe, Arc::new(ThreadPool::new(servers)))
    }

    /// As [`Pfs::open`] but sharing a caller-owned thread pool.
    pub fn open_with_pool(
        root: &Path,
        servers: usize,
        stripe: u64,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        if servers == 0 {
            return Err(Error::Config("pfs needs at least one server".into()));
        }
        if stripe == 0 {
            return Err(Error::Config("stripe size must be > 0".into()));
        }
        let meta_dir = root.join("meta");
        fs::create_dir_all(&meta_dir).map_err(|e| Error::io(&meta_dir, e))?;
        let mut server_dirs = Vec::with_capacity(servers);
        for s in 0..servers {
            let dir = root.join(format!("server{s}"));
            fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
            server_dirs.push(dir);
        }
        Ok(Self {
            meta_dir,
            server_dirs,
            default_stripe: stripe,
            pool,
            bytes_written: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            objects_written: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            verify_reads: true,
            append_coalesce: 0,
        })
    }

    /// Stripe-server count.
    pub fn servers(&self) -> usize {
        self.server_dirs.len()
    }

    /// Stripe unit used when a writer doesn't override it.
    pub fn default_stripe(&self) -> u64 {
        self.default_stripe
    }

    /// Snapshot of the tier's counters.
    pub fn stats(&self) -> PfsStats {
        PfsStats {
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            objects_written: self.objects_written.load(Ordering::Relaxed),
            reads: self.reads.load(Ordering::Relaxed),
        }
    }

    // -- path helpers -----------------------------------------------------

    /// Object keys may contain `/`; encode for flat filenames.
    fn enc(key: &str) -> String {
        key.replace('%', "%25").replace('/', "%2F")
    }

    fn meta_path(&self, key: &str) -> PathBuf {
        self.meta_dir.join(format!("{}.meta", Self::enc(key)))
    }

    fn datafile(&self, key: &str, server: usize) -> PathBuf {
        self.server_dirs[server].join(format!("{}.df", Self::enc(key)))
    }

    // -- metadata ----------------------------------------------------------

    fn write_meta(&self, key: &str, meta: &FileMeta) -> Result<()> {
        let path = self.meta_path(key);
        let text = format!(
            "size = {}\nstripe = {}\nservers = {}\ncrc = {}\n",
            meta.size, meta.stripe, meta.servers, meta.crc
        );
        // write-then-rename so readers never observe a torn meta file
        let tmp = path.with_extension("meta.tmp");
        fs::write(&tmp, text).map_err(|e| Error::io(&tmp, e))?;
        fs::rename(&tmp, &path).map_err(|e| Error::io(&path, e))?;
        Ok(())
    }

    fn read_meta(&self, key: &str) -> Result<FileMeta> {
        let path = self.meta_path(key);
        let text = fs::read_to_string(&path).map_err(|_| Error::NotFound(key.to_string()))?;
        FileMeta::parse(&text).ok_or_else(|| Error::Artifact(format!("bad meta for {key}")))
    }

    fn layout_of(&self, meta: &FileMeta) -> Result<StripeLayout> {
        StripeLayout::new(meta.stripe, meta.servers)
    }

    /// Write with explicit hints.
    pub fn write_with_hints(&self, key: &str, data: &[u8], hints: Hints) -> Result<()> {
        let stripe = hints.stripe_size.unwrap_or(self.default_stripe);
        let servers = hints
            .servers
            .unwrap_or(self.server_dirs.len())
            .min(self.server_dirs.len());
        let layout = StripeLayout::new(stripe, servers.max(1))?;

        // Partition the object into per-server contiguous datafile images
        // (batched: one write syscall per server, not per stripe).
        let segs = layout.map_range(data.len() as u64, 0, data.len() as u64);
        let mut per_server: Vec<Vec<u8>> = vec![Vec::new(); servers.max(1)];
        for seg in &segs {
            per_server[seg.server].extend_from_slice(
                &data[seg.object_offset as usize..(seg.object_offset + seg.len) as usize],
            );
        }

        let results: Vec<Result<()>> = {
            let paths: Vec<PathBuf> = (0..per_server.len())
                .map(|s| self.datafile(key, s))
                .collect();
            let payload: Vec<(PathBuf, Vec<u8>)> =
                paths.into_iter().zip(per_server).collect();
            let payload = Arc::new(payload);
            let p2 = Arc::clone(&payload);
            self.pool
                .map(payload.len(), move |i| {
                    let (path, bytes) = &p2[i];
                    fs::write(path, bytes).map_err(|e| Error::io(path, e))
                })
                .map_err(Error::Job)?
        };
        for r in results {
            r?;
        }

        // remove stale datafiles if the object previously spread wider
        for s in servers..self.server_dirs.len() {
            let p = self.datafile(key, s);
            let _ = fs::remove_file(p);
        }

        self.write_meta(
            key,
            &FileMeta {
                size: data.len() as u64,
                stripe,
                servers: servers.max(1),
                crc: checksum(data),
            },
        )?;
        self.bytes_written
            .fetch_add(data.len() as u64, Ordering::Relaxed);
        self.objects_written.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The layout geometry an object was stored with.
    pub fn object_layout(&self, key: &str) -> Result<(u64, StripeLayout)> {
        let meta = self.read_meta(key)?;
        Ok((meta.size, self.layout_of(&meta)?))
    }

    /// Start a streaming writer with explicit layout hints: each appended
    /// chunk is striped round-robin across the servers *as it arrives*
    /// (into per-server temp datafiles), and `commit` atomically publishes
    /// datafiles + metadata. See [`PfsWriter`].
    pub fn create_with_hints(&self, key: &str, hints: Hints) -> Result<PfsWriter<'_>> {
        let stripe = hints.stripe_size.unwrap_or(self.default_stripe);
        let servers = hints
            .servers
            .unwrap_or(self.server_dirs.len())
            .min(self.server_dirs.len())
            .max(1);
        let layout = StripeLayout::new(stripe, servers)?;
        let token = PFS_WRITER_SEQ.fetch_add(1, Ordering::Relaxed);
        Ok(PfsWriter {
            pfs: self,
            key: key.to_string(),
            layout,
            files: (0..servers).map(|_| None).collect(),
            token,
            written: 0,
            crc: Crc32::new(),
            coalesce: self.append_coalesce,
            carry: Vec::new(),
            finished: false,
        })
    }

    /// Read the byte range starting at `offset` into `buf` (whose length
    /// the caller has already clamped to the object size): segments are
    /// grouped per server, one pool task per involved server, single
    /// server reads go straight into `buf`. Returns bytes read.
    fn read_segments_into(
        &self,
        key: &str,
        meta: &FileMeta,
        layout: &StripeLayout,
        offset: u64,
        buf: &mut [u8],
    ) -> Result<usize> {
        let segs = layout.map_range(meta.size, offset, buf.len() as u64);
        let total: u64 = segs.iter().map(|s| s.len).sum();
        debug_assert!(total as usize <= buf.len());
        let base = offset;

        // Group segments per server: one task per involved server opens
        // its datafile once and serves every segment it owns, so a range
        // spanning many stripes engages all data nodes concurrently
        // instead of seeking through them one stripe at a time.
        let mut per_server: Vec<Vec<StripeSegment>> = vec![Vec::new(); self.server_dirs.len()];
        for seg in &segs {
            per_server[seg.server].push(*seg);
        }
        let jobs: Vec<(PathBuf, Vec<StripeSegment>)> = per_server
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(s, v)| (self.datafile(key, s), v))
            .collect();

        fn read_server(
            path: &Path,
            segs: &[StripeSegment],
            base: u64,
        ) -> Result<Vec<(usize, Vec<u8>)>> {
            let mut f = fs::File::open(path).map_err(|e| Error::io(path, e))?;
            let mut pieces = Vec::with_capacity(segs.len());
            for seg in segs {
                f.seek(SeekFrom::Start(seg.local_offset))
                    .map_err(|e| Error::io(path, e))?;
                let mut buf = vec![0u8; seg.len as usize];
                f.read_exact(&mut buf).map_err(|e| Error::io(path, e))?;
                pieces.push(((seg.object_offset - base) as usize, buf));
            }
            Ok(pieces)
        }

        if jobs.len() <= 1 {
            // Single-server fast path (e.g. a range within one stripe —
            // the common small two-level block read): no pool dispatch,
            // no temp buffers; read straight into the output.
            if let Some((path, segs)) = jobs.first() {
                let mut f = fs::File::open(path).map_err(|e| Error::io(path, e))?;
                for seg in segs {
                    f.seek(SeekFrom::Start(seg.local_offset))
                        .map_err(|e| Error::io(path, e))?;
                    let dst = (seg.object_offset - base) as usize;
                    f.read_exact(&mut buf[dst..dst + seg.len as usize])
                        .map_err(|e| Error::io(path, e))?;
                }
            }
        } else {
            let jobs = Arc::new(jobs);
            let j2 = Arc::clone(&jobs);
            let results: Vec<Result<Vec<(usize, Vec<u8>)>>> = self
                .pool
                .map(jobs.len(), move |i| {
                    let (path, segs) = &j2[i];
                    read_server(path, segs, base)
                })
                .map_err(Error::Job)?;
            for r in results {
                for (dst_start, piece) in r? {
                    buf[dst_start..dst_start + piece.len()].copy_from_slice(&piece);
                }
            }
        }
        self.bytes_read.fetch_add(total, Ordering::Relaxed);
        Ok(total as usize)
    }

    // -- crash recovery ----------------------------------------------------

    /// Atomically re-key an object: the metadata moves first (so `from`
    /// reads as `NotFound` from that point on), then each datafile.
    pub fn rename_object(&self, from: &str, to: &str) -> Result<()> {
        let src_meta = self.meta_path(from);
        let dst_meta = self.meta_path(to);
        fs::rename(&src_meta, &dst_meta).map_err(|e| Error::io(&src_meta, e))?;
        for s in 0..self.server_dirs.len() {
            let src = self.datafile(from, s);
            if src.exists() {
                let dst = self.datafile(to, s);
                fs::rename(&src, &dst).map_err(|e| Error::io(&src, e))?;
            }
        }
        Ok(())
    }

    /// Park `key` under [`QUARANTINE_NS`]; it then reads as `NotFound`.
    pub fn quarantine(&self, key: &str) -> Result<()> {
        self.rename_object(key, &format!("{QUARANTINE_NS}{key}"))
    }

    /// Whether `key`'s stored bytes are fully intact: every datafile the
    /// geometry expects is present with the right length and the object's
    /// CRC matches (checked even when [`Pfs::verify_reads`] is off). The
    /// caller has already checked `meta.servers` fits this store.
    fn object_intact(&self, key: &str, meta: &FileMeta) -> bool {
        match self.read(key) {
            Ok(data) => {
                if self.verify_reads {
                    true // read() already verified the CRC
                } else {
                    verify_checksum(key, &data, meta.crc).is_ok()
                }
            }
            Err(Error::NotFound(_)) => true, // raced a delete: nothing to judge
            Err(_) => false,
        }
    }

    /// Crash recovery for the PFS tier; see [`Recover`] for the contract.
    ///
    /// Four passes over the directory tree:
    ///
    /// 1. **Torn metadata temps** — `*.meta.tmp` files a crash interrupted
    ///    between write and rename are removed (the rename was the
    ///    visibility point; an unrenamed temp was never live).
    /// 2. **Writer temp datafiles** — `*.df.tmp-<token>` staging left by
    ///    abandoned [`PfsWriter`]s is removed; commits rename temps before
    ///    publishing metadata, so surviving temps belong to commits that
    ///    never happened.
    /// 3. **Object integrity** — every published object is re-read and
    ///    CRC-verified; objects with missing/truncated/mixed-version
    ///    datafiles or undecodable metadata are moved under
    ///    [`QUARANTINE_NS`] (never served, never silently deleted).
    /// 4. **Orphan datafiles** — `*.df` files with no owning metadata
    ///    (a crashed commit renamed them into place but died before the
    ///    meta landed) are removed; without metadata they were never
    ///    visible.
    /// 5. **Shuffle residue** — objects under [`SHUFFLE_NS`] are deleted,
    ///    never quarantined and never CRC-read (pass 3 drops them on
    ///    sight, intact or torn, before spending the verification read;
    ///    this pass sweeps any stragglers): shuffle spills are
    ///    recomputable intermediate job data, and a recovered store must
    ///    not hand a rebooted job server another job's stale runs.
    ///
    /// Cost: pass 3 reads every object once — recovery is a cold path and
    /// this is the only way to catch a mixed-version commit.
    pub fn recover_pfs(&self) -> Result<RecoveryReport> {
        let mut report = RecoveryReport::default();

        // pass 1+2: writer temps (anchored matcher: object keys merely
        // containing a temp-looking substring are not temps)
        let mut scan_temps = |dir: &Path| -> Result<()> {
            let entries = match fs::read_dir(dir) {
                Ok(e) => e,
                Err(e) => return Err(Error::io(dir, e)),
            };
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                if is_writer_temp(&name) && remove_existing(&entry.path())? {
                    report.temps_removed += 1;
                }
            }
            Ok(())
        };
        scan_temps(&self.meta_dir)?;
        for dir in &self.server_dirs {
            scan_temps(dir)?;
        }

        // pass 3: object integrity
        for key in self.list("") {
            if key.starts_with(QUARANTINE_NS) {
                continue; // already parked by a previous recovery
            }
            let meta = match self.read_meta(&key) {
                Ok(m) => m,
                Err(Error::NotFound(_)) => continue, // raced a delete
                Err(_) if key.starts_with(SHUFFLE_NS) => {
                    // torn shuffle spill: transient data, drop it outright
                    self.delete(&key)?;
                    report.shuffle_reaped += 1;
                    continue;
                }
                Err(_) => {
                    // undecodable metadata: park it
                    self.quarantine(&key)?;
                    report.quarantined.push(key);
                    continue;
                }
            };
            if key.starts_with(SHUFFLE_NS) {
                // transient spill: reaped regardless of integrity, so
                // skip the CRC read pass 3 would otherwise spend on it
                self.delete(&key)?;
                report.shuffle_reaped += 1;
                continue;
            }
            if meta.servers > self.server_dirs.len() {
                // Not corruption — the store was reopened with fewer
                // servers than the object was written across. Quarantining
                // here would destroy healthy data (and strand the wider
                // datafiles this store cannot even address); refuse and
                // tell the operator to reopen with the original geometry.
                return Err(Error::Config(format!(
                    "`{key}` is striped across {} servers but this store has {}; \
                     reopen with the original --pfs-servers before recovering",
                    meta.servers,
                    self.server_dirs.len()
                )));
            }
            if !self.object_intact(&key, &meta) {
                self.quarantine(&key)?;
                report.quarantined.push(key);
            }
        }

        // pass 4: orphan datafiles without metadata
        for dir in &self.server_dirs {
            let entries = fs::read_dir(dir).map_err(|e| Error::io(dir, e))?;
            for entry in entries.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                let Some(enc) = name.strip_suffix(".df") else {
                    continue;
                };
                let key = enc.replace("%2F", "/").replace("%25", "%");
                if !self.meta_path(&key).exists() && remove_existing(&entry.path())? {
                    report.orphans_removed += 1;
                }
            }
        }

        // pass 5: reap surviving (intact) shuffle spills — transient by
        // contract, a rebooted job server recomputes them (the shared
        // helper tolerates keys vanishing mid-reap)
        report.shuffle_reaped += crate::storage::reap_shuffle(self)?;
        Ok(report)
    }
}

impl Recover for Pfs {
    fn recover(&self) -> Result<RecoveryReport> {
        self.recover_pfs()
    }
}

/// Streaming reader over one striped object: geometry is snapshotted at
/// `open`, each `read_at` maps the requested range onto per-server stripe
/// segments and fans one task out per involved server (single-server
/// ranges skip the pool and read straight into the caller's buffer).
pub struct PfsReader<'a> {
    pfs: &'a Pfs,
    key: String,
    meta: FileMeta,
    layout: StripeLayout,
}

impl ObjectReader for PfsReader<'_> {
    fn len(&self) -> u64 {
        self.meta.size
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<usize> {
        let take = clamped_len(offset, buf.len(), self.meta.size);
        if take == 0 {
            return Ok(0);
        }
        self.pfs.reads.fetch_add(1, Ordering::Relaxed);
        self.pfs
            .read_segments_into(&self.key, &self.meta, &self.layout, offset, &mut buf[..take])
    }
}

/// Streaming striped writer: `append` splits each chunk across the server
/// datafiles round-robin as it arrives (OrangeFS layout: stripe `k` at
/// offset `(k / N) * stripe` of datafile `k % N`), accumulating a
/// streaming CRC. Chunks land in per-server `*.df.tmp-<token>` files that
/// are invisible to readers; `commit` renames them into place and then
/// publishes the metadata file (write-then-rename), so a concurrent
/// reader of a fresh key sees `NotFound` until the commit completes —
/// never a prefix. `abort` (or dropping uncommitted) deletes the temp
/// datafiles, leaving no orphan stripes.
pub struct PfsWriter<'a> {
    pfs: &'a Pfs,
    key: String,
    layout: StripeLayout,
    files: Vec<Option<fs::File>>,
    token: u64,
    written: u64,
    crc: Crc32,
    /// Coalescing threshold snapshotted from [`Pfs::append_coalesce`].
    coalesce: usize,
    /// Bytes buffered awaiting the next coalesced flush (always empty
    /// when `coalesce == 0`).
    carry: Vec<u8>,
    finished: bool,
}

impl PfsWriter<'_> {
    fn tmp_path(&self, server: usize) -> PathBuf {
        self.pfs.server_dirs[server].join(format!(
            "{}.df.tmp-{}",
            Pfs::enc(&self.key),
            self.token
        ))
    }

    /// Append one chunk (inherent form; [`ObjectWriter::append`] delegates
    /// here so in-crate callers can hold the concrete writer).
    ///
    /// The chunk's byte range is mapped onto stripe segments and grouped
    /// per server (each server's datafile receives ascending local
    /// offsets, so these are positioned appends). Large chunks touching
    /// several servers fan one scoped thread out per involved server —
    /// the same aggregate-bandwidth shape as the whole-object
    /// `write_with_hints`; small chunks skip the fan-out.
    pub fn append_chunk(&mut self, chunk: &[u8]) -> Result<()> {
        // below this, thread fan-out costs more than it overlaps
        const PARALLEL_APPEND_MIN: usize = 128 << 10;

        if chunk.is_empty() {
            return Ok(());
        }
        let end = self.written + chunk.len() as u64;
        let base = self.written;
        let segs = self.layout.map_range(end, base, chunk.len() as u64);
        let mut per_server: Vec<Vec<StripeSegment>> = vec![Vec::new(); self.files.len()];
        for seg in &segs {
            per_server[seg.server].push(*seg);
        }

        // open any involved datafile that has no handle yet
        let paths: Vec<PathBuf> = (0..self.files.len()).map(|s| self.tmp_path(s)).collect();
        for s in 0..self.files.len() {
            if !per_server[s].is_empty() && self.files[s].is_none() {
                let f = fs::OpenOptions::new()
                    .write(true)
                    .create(true)
                    .truncate(true)
                    .open(&paths[s])
                    .map_err(|e| Error::io(&paths[s], e))?;
                self.files[s] = Some(f);
            }
        }

        fn write_segments(
            f: &mut fs::File,
            segs: &[StripeSegment],
            base: u64,
            chunk: &[u8],
            path: &Path,
        ) -> Result<()> {
            for seg in segs {
                f.seek(SeekFrom::Start(seg.local_offset))
                    .map_err(|e| Error::io(path, e))?;
                let src = (seg.object_offset - base) as usize;
                f.write_all(&chunk[src..src + seg.len as usize])
                    .map_err(|e| Error::io(path, e))?;
            }
            Ok(())
        }

        let involved = per_server.iter().filter(|v| !v.is_empty()).count();
        if involved > 1 && chunk.len() >= PARALLEL_APPEND_MIN {
            let results: Vec<Result<()>> = std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .files
                    .iter_mut()
                    .enumerate()
                    .filter(|(s, _)| !per_server[*s].is_empty())
                    .map(|(s, slot)| {
                        // lint:allow(no-panic): the open loop above filled
                        // every slot this server-filter can select
                        let f = slot.as_mut().expect("opened above");
                        let segs = &per_server[s];
                        let path = &paths[s];
                        scope.spawn(move || write_segments(f, segs, base, chunk, path))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| {
                        // a panicked leg fails the append instead of
                        // tearing down the writer's thread
                        h.join().unwrap_or_else(|_| {
                            Err(Error::Job("pfs append leg panicked".into()))
                        })
                    })
                    .collect()
            });
            for r in results {
                r?;
            }
        } else {
            for s in 0..self.files.len() {
                if per_server[s].is_empty() {
                    continue;
                }
                // lint:allow(no-panic): the open loop above filled every
                // slot with segments to write
                let f = self.files[s].as_mut().expect("opened above");
                write_segments(f, &per_server[s], base, chunk, &paths[s])?;
            }
        }
        self.crc.update(chunk);
        self.written = end;
        Ok(())
    }

    /// Stripe out the coalescing carry, keeping its allocation for the
    /// next batch.
    fn flush_carry(&mut self) -> Result<()> {
        if self.carry.is_empty() {
            return Ok(());
        }
        let mut full = std::mem::take(&mut self.carry);
        self.append_chunk(&full)?;
        full.clear();
        self.carry = full;
        Ok(())
    }

    /// Bytes appended so far (including any not-yet-flushed carry).
    pub fn bytes_written(&self) -> u64 {
        self.written + self.carry.len() as u64
    }

    /// Publish: rename temp datafiles into place, drop stale wider ones,
    /// then write the metadata file (the visibility point — a fresh key
    /// stays `NotFound` until the meta lands).
    ///
    /// Overwrite caveat: as with the whole-object `write`, datafiles of an
    /// *existing* key are replaced before the new meta publishes, so a
    /// reader racing an overwrite commit can hit a CRC-mismatch window.
    /// The store contract is write-once-read-many; racing reads against
    /// overwrites of the same key sit outside it.
    pub fn finish(mut self) -> Result<()> {
        // a coalescing writer may still hold a sub-threshold batch
        if !self.carry.is_empty() {
            let full = std::mem::take(&mut self.carry);
            if let Err(e) = self.append_chunk(&full) {
                self.cleanup();
                return Err(e);
            }
        }
        self.finished = true;
        let mut err: Option<Error> = None;
        let mut touched_live = false; // any rename/unlink of live datafiles ran
        for s in 0..self.files.len() {
            let had_data = self.files[s].take().is_some(); // close before rename
            if err.is_some() {
                continue; // cleanup happens below
            }
            let tmp = self.tmp_path(s);
            let dst = self.pfs.datafile(&self.key, s);
            if had_data {
                match fs::rename(&tmp, &dst) {
                    Ok(()) => touched_live = true,
                    Err(e) => err = Some(Error::io(&dst, e)),
                }
            } else {
                // no stripes landed here (small object): drop any stale
                // datafile a previous, larger version left behind
                let _ = fs::remove_file(&dst);
                touched_live = true;
            }
        }
        if err.is_none() {
            for s in self.files.len()..self.pfs.server_dirs.len() {
                let _ = fs::remove_file(self.pfs.datafile(&self.key, s));
            }
            if let Err(e) = self.pfs.write_meta(
                &self.key,
                &FileMeta {
                    size: self.written,
                    stripe: self.layout.stripe_size,
                    servers: self.layout.servers,
                    crc: self.crc.finish(),
                },
            ) {
                err = Some(e);
            }
        }
        if let Some(e) = err {
            // A commit that returns Err leaks no temp datafiles. For a
            // fresh key (no meta ever published) the already-renamed
            // datafiles are invisible garbage — drop them too. For an
            // overwrite whose live datafiles were already partially
            // replaced, the old meta now describes mixed-version bytes:
            // drop the meta as well, so the key reads as a clean
            // `NotFound` instead of serving corruption (the replaced
            // version is unrecoverable either way — the WORM-contract
            // overwrite caveat documented on this writer).
            for s in 0..self.files.len() {
                let _ = fs::remove_file(self.tmp_path(s));
            }
            let meta = self.pfs.meta_path(&self.key);
            if !meta.exists() || touched_live {
                let _ = fs::remove_file(&meta);
                for s in 0..self.pfs.server_dirs.len() {
                    let _ = fs::remove_file(self.pfs.datafile(&self.key, s));
                }
            }
            return Err(e);
        }
        self.pfs.bytes_written.fetch_add(self.written, Ordering::Relaxed);
        self.pfs.objects_written.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Discard the staged temp datafiles without publishing.
    pub fn cancel(mut self) -> Result<()> {
        self.cleanup();
        Ok(())
    }

    fn cleanup(&mut self) {
        self.finished = true;
        self.carry.clear();
        for s in 0..self.files.len() {
            self.files[s] = None;
            let _ = fs::remove_file(self.tmp_path(s));
        }
    }
}

impl Drop for PfsWriter<'_> {
    fn drop(&mut self) {
        if !self.finished {
            self.cleanup();
        }
    }
}

impl ObjectWriter for PfsWriter<'_> {
    fn append(&mut self, chunk: &[u8]) -> Result<()> {
        if self.coalesce == 0 {
            return self.append_chunk(chunk);
        }
        // already-large chunks skip the copy through the carry
        if self.carry.is_empty() && chunk.len() >= self.coalesce {
            return self.append_chunk(chunk);
        }
        self.carry.extend_from_slice(chunk);
        if self.carry.len() >= self.coalesce {
            self.flush_carry()?;
        }
        Ok(())
    }

    fn append_vectored(&mut self, parts: &[&[u8]]) -> Result<()> {
        match parts {
            [] => Ok(()),
            [one] => ObjectWriter::append(self, one),
            _ => {
                let total: usize = parts.iter().map(|p| p.len()).sum();
                if self.coalesce != 0 {
                    // pack straight into the carry: at most one striped
                    // fan-out per threshold's worth of parts
                    self.carry.reserve(total);
                    for p in parts {
                        self.carry.extend_from_slice(p);
                    }
                    if self.carry.len() >= self.coalesce {
                        self.flush_carry()?;
                    }
                    Ok(())
                } else {
                    // append-through mode: join once so the stripe
                    // fan-out sees a single large chunk instead of N
                    // sub-threshold ones
                    let mut joined = Vec::with_capacity(total);
                    for p in parts {
                        joined.extend_from_slice(p);
                    }
                    self.append_chunk(&joined)
                }
            }
        }
    }

    fn written(&self) -> u64 {
        self.bytes_written()
    }

    fn commit(self: Box<Self>) -> Result<()> {
        (*self).finish()
    }

    fn abort(self: Box<Self>) -> Result<()> {
        (*self).cancel()
    }
}

#[derive(Debug, Clone, Copy)]
struct FileMeta {
    size: u64,
    stripe: u64,
    servers: usize,
    crc: u32,
}

impl FileMeta {
    fn parse(text: &str) -> Option<Self> {
        let mut size = None;
        let mut stripe = None;
        let mut servers = None;
        let mut crc = None;
        for line in text.lines() {
            let (k, v) = line.split_once('=')?;
            let v = v.trim();
            match k.trim() {
                "size" => size = v.parse().ok(),
                "stripe" => stripe = v.parse().ok(),
                "servers" => servers = v.parse().ok(),
                "crc" => crc = v.parse().ok(),
                _ => return None,
            }
        }
        Some(Self {
            size: size?,
            stripe: stripe?,
            servers: servers?,
            crc: crc?,
        })
    }
}

impl ObjectStore for Pfs {
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
        let meta = self.read_meta(key)?;
        let layout = self.layout_of(&meta)?;
        Ok(Box::new(PfsReader {
            pfs: self,
            key: key.to_string(),
            meta,
            layout,
        }))
    }

    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
        Ok(Box::new(self.create_with_hints(key, Hints::default())?))
    }

    fn stat(&self, key: &str) -> Result<ObjectMeta> {
        Ok(ObjectMeta {
            key: key.to_string(),
            size: self.read_meta(key)?.size,
        })
    }

    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        self.write_with_hints(key, data, Hints::default())
    }

    fn read(&self, key: &str) -> Result<Vec<u8>> {
        let meta = self.read_meta(key)?;
        let layout = self.layout_of(&meta)?;
        self.reads.fetch_add(1, Ordering::Relaxed);

        // Parallel full-datafile reads, then de-stripe.
        let servers = meta.servers;
        let paths: Vec<PathBuf> = (0..servers).map(|s| self.datafile(key, s)).collect();
        let paths = Arc::new(paths);
        let p2 = Arc::clone(&paths);
        let images: Vec<Result<Vec<u8>>> = self
            .pool
            .map(servers, move |s| {
                let path = &p2[s];
                if meta.size == 0 {
                    return Ok(Vec::new());
                }
                match fs::read(path) {
                    Ok(v) => Ok(v),
                    // a server with no stripes for a tiny object has no file
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
                    Err(e) => Err(Error::io(path, e)),
                }
            })
            .map_err(Error::Job)?;

        let mut out = vec![0u8; meta.size as usize];
        let mut cursors = vec![0usize; servers];
        let segs = layout.map_range(meta.size, 0, meta.size);
        for seg in segs {
            let img = match &images[seg.server] {
                Ok(v) => v,
                Err(e) => return Err(Error::Artifact(format!("server {} read: {e}", seg.server))),
            };
            let start = cursors[seg.server];
            let end = start + seg.len as usize;
            if end > img.len() {
                return Err(Error::Artifact(format!(
                    "truncated datafile for {key} on server {}",
                    seg.server
                )));
            }
            out[seg.object_offset as usize..(seg.object_offset + seg.len) as usize]
                .copy_from_slice(&img[start..end]);
            cursors[seg.server] = end;
        }

        if self.verify_reads {
            verify_checksum(key, &out, meta.crc)?;
        }
        self.bytes_read.fetch_add(meta.size, Ordering::Relaxed);
        Ok(out)
    }

    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        let meta = self.read_meta(key)?;
        let layout = self.layout_of(&meta)?;
        self.reads.fetch_add(1, Ordering::Relaxed);
        let mut out = vec![0u8; crate::storage::clamped_len(offset, len, meta.size)];
        self.read_segments_into(key, &meta, &layout, offset, &mut out)?;
        Ok(out)
    }

    fn size(&self, key: &str) -> Result<u64> {
        Ok(self.read_meta(key)?.size)
    }

    fn exists(&self, key: &str) -> bool {
        self.meta_path(key).exists()
    }

    fn delete(&self, key: &str) -> Result<()> {
        // idempotent for missing keys, but a file the filesystem refuses
        // to remove is a real error: rollback paths depend on delete
        // actually deleting (see `Error::RecoveryNeeded`)
        remove_existing(&self.meta_path(key))?;
        for s in 0..self.server_dirs.len() {
            remove_existing(&self.datafile(key, s))?;
        }
        Ok(())
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys = Vec::new();
        if let Ok(entries) = fs::read_dir(&self.meta_dir) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if let Some(enc) = name.strip_suffix(".meta") {
                    let key = enc.replace("%2F", "/").replace("%25", "%");
                    if key.starts_with(prefix) {
                        keys.push(key);
                    }
                }
            }
        }
        keys.sort();
        keys
    }

    fn kind(&self) -> &'static str {
        "pfs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg32;

    fn rand_data(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg32::new(seed, 1);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    fn open(dir: &TempDir, servers: usize, stripe: u64) -> Pfs {
        Pfs::open(dir.path(), servers, stripe).unwrap()
    }

    #[test]
    fn roundtrip_various_sizes() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 64);
        for (i, n) in [0usize, 1, 63, 64, 65, 128, 1000, 10_000].iter().enumerate() {
            let key = format!("obj{i}");
            let data = rand_data(*n, i as u64);
            pfs.write(&key, &data).unwrap();
            assert_eq!(pfs.read(&key).unwrap(), data, "size {n}");
            assert_eq!(pfs.size(&key).unwrap(), *n as u64);
        }
    }

    #[test]
    fn stripes_actually_distributed() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 4, 32);
        pfs.write("spread", &rand_data(256, 7)).unwrap();
        // each server holds a 64-byte datafile (2 stripes of 32)
        for s in 0..4 {
            let df = dir.path().join(format!("server{s}")).join("spread.df");
            assert_eq!(fs::metadata(df).unwrap().len(), 64, "server {s}");
        }
    }

    #[test]
    fn read_range_matches_slice() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 50);
        let data = rand_data(1000, 9);
        pfs.write("r", &data).unwrap();
        for (off, len) in [(0usize, 1000usize), (0, 10), (45, 10), (999, 1), (990, 100), (1000, 5)] {
            let got = pfs.read_range("r", off as u64, len).unwrap();
            let end = (off + len).min(1000);
            assert_eq!(got, &data[off.min(1000)..end], "off={off} len={len}");
        }
    }

    #[test]
    fn hints_override_layout() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 4, 64);
        let data = rand_data(512, 3);
        pfs.write_with_hints(
            "hinted",
            &data,
            Hints {
                stripe_size: Some(128),
                servers: Some(2),
            },
        )
        .unwrap();
        let (size, layout) = pfs.object_layout("hinted").unwrap();
        assert_eq!(size, 512);
        assert_eq!(layout.stripe_size, 128);
        assert_eq!(layout.servers, 2);
        assert_eq!(pfs.read("hinted").unwrap(), data);
        // servers 2..4 must hold nothing
        assert!(!dir.path().join("server2").join("hinted.df").exists());
    }

    #[test]
    fn rewrite_shrinks_cleanly() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 16);
        pfs.write("k", &rand_data(160, 1)).unwrap();
        let small = rand_data(8, 2);
        pfs.write("k", &small).unwrap();
        assert_eq!(pfs.read("k").unwrap(), small);
        assert_eq!(pfs.size("k").unwrap(), 8);
    }

    #[test]
    fn corruption_detected_on_read() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("c", &rand_data(100, 5)).unwrap();
        // flip a byte in server0's datafile
        let df = dir.path().join("server0").join("c.df");
        let mut bytes = fs::read(&df).unwrap();
        bytes[0] ^= 0xFF;
        fs::write(&df, bytes).unwrap();
        let err = pfs.read("c").unwrap_err();
        assert!(matches!(err, Error::ChecksumMismatch { .. }), "{err}");
    }

    #[test]
    fn missing_object_is_not_found() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        assert!(matches!(pfs.read("ghost"), Err(Error::NotFound(_))));
        assert!(!pfs.exists("ghost"));
    }

    #[test]
    fn delete_is_idempotent_and_complete() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("d", &rand_data(100, 6)).unwrap();
        pfs.delete("d").unwrap();
        pfs.delete("d").unwrap();
        assert!(!pfs.exists("d"));
        assert!(!dir.path().join("server0").join("d.df").exists());
        assert!(!dir.path().join("server1").join("d.df").exists());
    }

    #[test]
    fn list_decodes_slashed_keys() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("in/part-0", b"a").unwrap();
        pfs.write("in/part-1", b"b").unwrap();
        pfs.write("out/part-0", b"c").unwrap();
        assert_eq!(pfs.list("in/"), vec!["in/part-0", "in/part-1"]);
        assert_eq!(pfs.list(""), vec!["in/part-0", "in/part-1", "out/part-0"]);
    }

    #[test]
    fn percent_keys_roundtrip() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("we%ird/na%2Fme", b"x").unwrap();
        assert_eq!(pfs.list("we%"), vec!["we%ird/na%2Fme"]);
        assert_eq!(pfs.read("we%ird/na%2Fme").unwrap(), b"x");
    }

    #[test]
    fn stats_accumulate() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("s", &rand_data(100, 8)).unwrap();
        let _ = pfs.read("s").unwrap();
        let _ = pfs.read_range("s", 0, 10).unwrap();
        let st = pfs.stats();
        assert_eq!(st.bytes_written, 100);
        assert_eq!(st.bytes_read, 110);
        assert_eq!(st.objects_written, 1);
        assert_eq!(st.reads, 2);
    }

    #[test]
    fn concurrent_range_reads_are_consistent() {
        let dir = TempDir::new("pfs-conc").unwrap();
        let pfs = Arc::new(open(&dir, 4, 64));
        let data = rand_data(64 * 41, 11); // odd stripe count over 4 servers
        pfs.write("wide", &data).unwrap();
        std::thread::scope(|s| {
            for t in 0..4usize {
                let pfs = Arc::clone(&pfs);
                let data = &data;
                s.spawn(move || {
                    for i in 0..20 {
                        let off = (t * 97 + i * 131) % data.len();
                        let len = 777.min(data.len() - off);
                        assert_eq!(
                            pfs.read_range("wide", off as u64, len).unwrap(),
                            &data[off..off + len],
                            "t={t} off={off}"
                        );
                    }
                });
            }
        });
    }

    #[test]
    fn empty_object_roundtrip() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 64);
        pfs.write("empty", b"").unwrap();
        assert_eq!(pfs.read("empty").unwrap(), Vec::<u8>::new());
        assert!(pfs.exists("empty"));
    }

    // -- v2 handle surface ------------------------------------------------

    #[test]
    fn streaming_writer_matches_whole_object_write() {
        let dir = TempDir::new("pfs-w").unwrap();
        let pfs = open(&dir, 3, 64);
        for (i, n) in [0usize, 1, 63, 64, 65, 200, 1000, 10_000].iter().enumerate() {
            let data = rand_data(*n, 40 + i as u64);
            let key = format!("s{i}");
            let mut w = pfs.create_with_hints(&key, Hints::default()).unwrap();
            // append in awkward chunk sizes to cross stripe boundaries
            for chunk in data.chunks(37) {
                w.append_chunk(chunk).unwrap();
            }
            assert_eq!(w.bytes_written(), *n as u64);
            w.finish().unwrap();
            // whole-object read path CRC-verifies the streamed checksum
            assert_eq!(pfs.read(&key).unwrap(), data, "size {n}");
            assert_eq!(pfs.size(&key).unwrap(), *n as u64);
        }
    }

    #[test]
    fn streaming_writer_parallel_fanout_large_chunks() {
        // chunks ≥ 128 KiB spanning several servers take the scoped-thread
        // fan-out path; the bytes must still land exactly
        let dir = TempDir::new("pfs-par").unwrap();
        let pfs = open(&dir, 4, 32 << 10); // 32 KiB stripes over 4 servers
        let data = rand_data(1 << 20, 55);
        let mut w = pfs.create_with_hints("wide", Hints::default()).unwrap();
        for chunk in data.chunks(256 << 10) {
            w.append_chunk(chunk).unwrap();
        }
        w.finish().unwrap();
        assert_eq!(pfs.read("wide").unwrap(), data);
        assert_eq!(pfs.size("wide").unwrap(), 1 << 20);
    }

    #[test]
    fn streaming_writer_invisible_until_commit_and_abort_cleans() {
        let dir = TempDir::new("pfs-vis").unwrap();
        let pfs = open(&dir, 2, 32);
        let data = rand_data(300, 9);
        {
            let mut w = pfs.create_with_hints("x", Hints::default()).unwrap();
            w.append_chunk(&data[..200]).unwrap();
            assert!(!pfs.exists("x"), "no meta before commit");
            assert!(matches!(pfs.read("x"), Err(Error::NotFound(_))));
            w.cancel().unwrap();
        }
        assert!(!pfs.exists("x"));
        // no orphan stripes: server dirs hold no files at all
        for s in 0..2 {
            let n = fs::read_dir(dir.path().join(format!("server{s}")))
                .unwrap()
                .count();
            assert_eq!(n, 0, "server {s} must be empty after abort");
        }
        // dropping an uncommitted writer also cleans up
        {
            let mut w = pfs.create_with_hints("y", Hints::default()).unwrap();
            w.append_chunk(&data).unwrap();
        }
        for s in 0..2 {
            let n = fs::read_dir(dir.path().join(format!("server{s}")))
                .unwrap()
                .count();
            assert_eq!(n, 0, "server {s} must be empty after drop");
        }
    }

    #[test]
    fn streaming_rewrite_shrinks_cleanly() {
        let dir = TempDir::new("pfs-shrink").unwrap();
        let pfs = open(&dir, 3, 16);
        pfs.write("k", &rand_data(160, 1)).unwrap();
        let small = rand_data(8, 2);
        let mut w = pfs.create_with_hints("k", Hints::default()).unwrap();
        w.append_chunk(&small).unwrap();
        w.finish().unwrap();
        assert_eq!(pfs.read("k").unwrap(), small);
        // wider stale datafiles must be gone
        assert!(!dir.path().join("server1").join("k.df").exists());
        assert!(!dir.path().join("server2").join("k.df").exists());
    }

    #[test]
    fn coalescing_writer_matches_append_through() {
        // same bytes, same final object — only the flush batching differs
        let dir = TempDir::new("pfs-co").unwrap();
        let mut pfs = open(&dir, 3, 64);
        pfs.append_coalesce = 256;
        let data = rand_data(5000, 77);
        let mut w = pfs.create_with_hints("co", Hints::default()).unwrap();
        for chunk in data.chunks(37) {
            w.append(chunk).unwrap(); // trait entry: coalesces
        }
        assert_eq!(w.written(), 5000, "written() must include the carry");
        w.finish().unwrap();
        assert_eq!(pfs.read("co").unwrap(), data, "CRC-verified readback");

        // vectored form, mixed with large chunks that bypass the carry
        let mut w = pfs.create_with_hints("vec", Hints::default()).unwrap();
        let parts: Vec<&[u8]> = data.chunks(41).collect();
        w.append_vectored(&parts).unwrap();
        w.append(&data[..300]).unwrap();
        w.finish().unwrap();
        let mut expect = data.clone();
        expect.extend_from_slice(&data[..300]);
        assert_eq!(pfs.read("vec").unwrap(), expect);
    }

    #[test]
    fn coalescing_writer_abort_and_drop_leave_no_carry_debris() {
        let dir = TempDir::new("pfs-co-ab").unwrap();
        let mut pfs = open(&dir, 2, 32);
        pfs.append_coalesce = 1 << 20; // everything stays in the carry
        let data = rand_data(500, 5);
        {
            let mut w = pfs.create_with_hints("a", Hints::default()).unwrap();
            w.append(&data).unwrap();
            w.cancel().unwrap();
        }
        {
            let mut w = pfs.create_with_hints("b", Hints::default()).unwrap();
            w.append(&data).unwrap();
            // dropped uncommitted
        }
        assert!(!pfs.exists("a"));
        assert!(!pfs.exists("b"));
        for s in 0..2 {
            let n = fs::read_dir(dir.path().join(format!("server{s}")))
                .unwrap()
                .count();
            assert_eq!(n, 0, "server {s} must be empty");
        }
    }

    #[test]
    fn reader_read_at_matches_slices() {
        let dir = TempDir::new("pfs-r").unwrap();
        let pfs = open(&dir, 3, 50);
        let data = rand_data(1000, 12);
        pfs.write("r", &data).unwrap();
        let r = pfs.open("r").unwrap();
        assert_eq!(r.len(), 1000);
        for (off, len) in [(0usize, 1000usize), (0, 10), (45, 10), (49, 2), (999, 1), (990, 100)] {
            let mut buf = vec![0u8; len];
            let n = r.read_at(off as u64, &mut buf).unwrap();
            let end = (off + len).min(1000);
            assert_eq!(n, end - off, "off={off} len={len}");
            assert_eq!(&buf[..n], &data[off..end], "off={off} len={len}");
        }
        let mut buf = [0u8; 4];
        assert_eq!(r.read_at(1000, &mut buf).unwrap(), 0, "at EOF");
        assert_eq!(r.read_at(5000, &mut buf).unwrap(), 0, "past EOF");
    }

    // -- crash recovery ----------------------------------------------------

    #[test]
    fn recover_on_clean_store_is_clean() {
        let dir = TempDir::new("pfs-rec0").unwrap();
        let pfs = open(&dir, 3, 64);
        pfs.write("a", &rand_data(500, 60)).unwrap();
        pfs.write("b/c", &rand_data(100, 61)).unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert!(report.is_clean(), "{report}");
        assert_eq!(pfs.read("a").unwrap(), rand_data(500, 60));
    }

    #[test]
    fn recover_removes_writer_temps_and_meta_temps() {
        let dir = TempDir::new("pfs-rec1").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("live", &rand_data(100, 62)).unwrap();
        // debris a killed process would leave
        fs::write(dir.path().join("server0").join("k.df.tmp-7"), b"junk").unwrap();
        fs::write(dir.path().join("server1").join("k.df.tmp-7"), b"junk").unwrap();
        fs::write(dir.path().join("meta").join("k.meta.tmp"), b"size = 4\n").unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert_eq!(report.temps_removed, 3, "{report}");
        assert!(report.quarantined.is_empty());
        assert!(!dir.path().join("server0").join("k.df.tmp-7").exists());
        assert!(!dir.path().join("meta").join("k.meta.tmp").exists());
        assert_eq!(pfs.read("live").unwrap(), rand_data(100, 62), "live object untouched");
    }

    #[test]
    fn recover_quarantines_truncated_object() {
        let dir = TempDir::new("pfs-rec2").unwrap();
        let pfs = open(&dir, 2, 32);
        let data = rand_data(200, 63);
        pfs.write("bad", &data).unwrap();
        pfs.write("good", &data).unwrap();
        // truncate one datafile: the object can no longer serve fully
        let df = dir.path().join("server1").join("bad.df");
        let bytes = fs::read(&df).unwrap();
        fs::write(&df, &bytes[..bytes.len() / 2]).unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert_eq!(report.quarantined, vec!["bad".to_string()], "{report}");
        assert!(matches!(pfs.read("bad"), Err(Error::NotFound(_))), "quarantined → NotFound");
        assert!(!pfs.exists("bad"));
        assert_eq!(pfs.read("good").unwrap(), data, "healthy neighbour untouched");
        // quarantined bytes are preserved, and a second pass is clean
        assert_eq!(pfs.list(QUARANTINE_NS), vec![format!("{QUARANTINE_NS}bad")]);
        assert!(pfs.recover_pfs().unwrap().is_clean());
    }

    #[test]
    fn recover_quarantines_corrupt_object() {
        let dir = TempDir::new("pfs-rec3").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("c", &rand_data(100, 64)).unwrap();
        let df = dir.path().join("server0").join("c.df");
        let mut bytes = fs::read(&df).unwrap();
        bytes[0] ^= 0xFF;
        fs::write(&df, bytes).unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert_eq!(report.quarantined, vec!["c".to_string()]);
        assert!(matches!(pfs.read("c"), Err(Error::NotFound(_))));
    }

    #[test]
    fn recover_removes_orphan_datafiles_without_meta() {
        let dir = TempDir::new("pfs-rec4").unwrap();
        let pfs = open(&dir, 2, 32);
        // a crashed commit renamed datafiles into place but never wrote meta
        fs::write(dir.path().join("server0").join("ghost.df"), b"abc").unwrap();
        fs::write(dir.path().join("server1").join("ghost.df"), b"def").unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert_eq!(report.orphans_removed, 2, "{report}");
        assert!(!dir.path().join("server0").join("ghost.df").exists());
        assert!(!pfs.exists("ghost"));
    }

    #[test]
    fn recover_quarantines_undecodable_meta() {
        let dir = TempDir::new("pfs-rec5").unwrap();
        let pfs = open(&dir, 2, 32);
        fs::write(dir.path().join("meta").join("junk.meta"), b"not = a\nmeta").unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert_eq!(report.quarantined, vec!["junk".to_string()]);
        assert!(!pfs.exists("junk"));
    }

    #[test]
    fn recover_refuses_a_narrower_server_count() {
        let dir = TempDir::new("pfs-rec6").unwrap();
        let data = rand_data(300, 65);
        {
            let pfs = open(&dir, 4, 32);
            pfs.write("wide", &data).unwrap();
        }
        // reopened with fewer servers: recover must refuse, not quarantine
        let pfs = open(&dir, 2, 32);
        let err = pfs.recover_pfs().unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        // nothing was touched: the original geometry still reads cleanly
        let pfs = open(&dir, 4, 32);
        assert!(pfs.recover_pfs().unwrap().is_clean());
        assert_eq!(pfs.read("wide").unwrap(), data);
    }

    #[test]
    fn recover_spares_keys_that_merely_look_like_temps() {
        let dir = TempDir::new("pfs-rec7").unwrap();
        let pfs = open(&dir, 2, 32);
        let data = rand_data(150, 66);
        // a published object whose *name* contains the temp infix
        pfs.write("backup/app.df.tmp-old", &data).unwrap();
        let report = pfs.recover_pfs().unwrap();
        assert!(report.is_clean(), "{report}");
        assert_eq!(pfs.read("backup/app.df.tmp-old").unwrap(), data);
    }

    #[test]
    fn delete_surfaces_real_filesystem_errors() {
        // deleting a missing key stays Ok (idempotence contract)
        let dir = TempDir::new("pfs-del").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.delete("never-written").unwrap();
    }
}
