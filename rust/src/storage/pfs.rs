//! The parallel-file-system tier (the paper's OrangeFS).
//!
//! Objects are striped round-robin across `servers` directories — each
//! directory standing in for one data node's RAID volume — with one
//! *datafile* per server per object (exactly OrangeFS's layout: a file is
//! N datafiles, stripe k lives at offset `(k / N) * stripe` of datafile
//! `k % N`). A small metadata file records size/geometry/CRC, playing the
//! metadata-server role.
//!
//! The "Tachyon-OFS plug-in hints" of §3 map to [`Hints`]: per-write
//! stripe-size and server-count overrides.
//!
//! Server I/O is issued in parallel (one task per server via the shared
//! [`ThreadPool`]), which is what gives the tier its aggregate-bandwidth
//! behaviour: a read of one object engages every data node at once. This
//! covers all three access shapes: whole-object writes, whole-object
//! reads, and ranged reads (`read_range` groups the requested stripes per
//! server and fans one task out per involved server — the path the
//! two-level store's block reads ride).

use std::fs;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::storage::block::{checksum, verify_checksum};
use crate::storage::layout::{StripeLayout, StripeSegment};
use crate::storage::ObjectStore;
use crate::util::pool::ThreadPool;

/// Per-write layout overrides (the plug-in "hints" of §3.1).
#[derive(Debug, Clone, Copy, Default)]
pub struct Hints {
    /// Override stripe size for this object.
    pub stripe_size: Option<u64>,
    /// Use only the first `n` servers (e.g. to emulate fewer data nodes).
    pub servers: Option<usize>,
}

/// Counters for the tier.
#[derive(Debug, Clone, Copy, Default)]
pub struct PfsStats {
    pub bytes_written: u64,
    pub bytes_read: u64,
    pub objects_written: u64,
    pub reads: u64,
}

/// Striped object store over `servers` directories.
pub struct Pfs {
    meta_dir: PathBuf,
    server_dirs: Vec<PathBuf>,
    default_stripe: u64,
    pool: Arc<ThreadPool>,
    bytes_written: AtomicU64,
    bytes_read: AtomicU64,
    objects_written: AtomicU64,
    reads: AtomicU64,
    /// Verify stripe CRCs on every read (on by default; the ablation bench
    /// measures its cost).
    pub verify_reads: bool,
}

impl Pfs {
    /// Open (creating directories) a PFS rooted at `root` with `servers`
    /// server directories and `stripe` default stripe size.
    pub fn open(root: &Path, servers: usize, stripe: u64) -> Result<Self> {
        Self::open_with_pool(root, servers, stripe, Arc::new(ThreadPool::new(servers)))
    }

    /// As [`Pfs::open`] but sharing a caller-owned thread pool.
    pub fn open_with_pool(
        root: &Path,
        servers: usize,
        stripe: u64,
        pool: Arc<ThreadPool>,
    ) -> Result<Self> {
        if servers == 0 {
            return Err(Error::Config("pfs needs at least one server".into()));
        }
        if stripe == 0 {
            return Err(Error::Config("stripe size must be > 0".into()));
        }
        let meta_dir = root.join("meta");
        fs::create_dir_all(&meta_dir).map_err(|e| Error::io(&meta_dir, e))?;
        let mut server_dirs = Vec::with_capacity(servers);
        for s in 0..servers {
            let dir = root.join(format!("server{s}"));
            fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
            server_dirs.push(dir);
        }
        Ok(Self {
            meta_dir,
            server_dirs,
            default_stripe: stripe,
            pool,
            bytes_written: AtomicU64::new(0),
            bytes_read: AtomicU64::new(0),
            objects_written: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            verify_reads: true,
        })
    }

    pub fn servers(&self) -> usize {
        self.server_dirs.len()
    }

    pub fn default_stripe(&self) -> u64 {
        self.default_stripe
    }

    pub fn stats(&self) -> PfsStats {
        PfsStats {
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            objects_written: self.objects_written.load(Ordering::Relaxed),
            reads: self.reads.load(Ordering::Relaxed),
        }
    }

    // -- path helpers -----------------------------------------------------

    /// Object keys may contain `/`; encode for flat filenames.
    fn enc(key: &str) -> String {
        key.replace('%', "%25").replace('/', "%2F")
    }

    fn meta_path(&self, key: &str) -> PathBuf {
        self.meta_dir.join(format!("{}.meta", Self::enc(key)))
    }

    fn datafile(&self, key: &str, server: usize) -> PathBuf {
        self.server_dirs[server].join(format!("{}.df", Self::enc(key)))
    }

    // -- metadata ----------------------------------------------------------

    fn write_meta(&self, key: &str, meta: &ObjectMeta) -> Result<()> {
        let path = self.meta_path(key);
        let text = format!(
            "size = {}\nstripe = {}\nservers = {}\ncrc = {}\n",
            meta.size, meta.stripe, meta.servers, meta.crc
        );
        // write-then-rename so readers never observe a torn meta file
        let tmp = path.with_extension("meta.tmp");
        fs::write(&tmp, text).map_err(|e| Error::io(&tmp, e))?;
        fs::rename(&tmp, &path).map_err(|e| Error::io(&path, e))?;
        Ok(())
    }

    fn read_meta(&self, key: &str) -> Result<ObjectMeta> {
        let path = self.meta_path(key);
        let text = fs::read_to_string(&path).map_err(|_| Error::NotFound(key.to_string()))?;
        ObjectMeta::parse(&text).ok_or_else(|| Error::Artifact(format!("bad meta for {key}")))
    }

    fn layout_of(&self, meta: &ObjectMeta) -> Result<StripeLayout> {
        StripeLayout::new(meta.stripe, meta.servers)
    }

    /// Write with explicit hints.
    pub fn write_with_hints(&self, key: &str, data: &[u8], hints: Hints) -> Result<()> {
        let stripe = hints.stripe_size.unwrap_or(self.default_stripe);
        let servers = hints
            .servers
            .unwrap_or(self.server_dirs.len())
            .min(self.server_dirs.len());
        let layout = StripeLayout::new(stripe, servers.max(1))?;

        // Partition the object into per-server contiguous datafile images
        // (batched: one write syscall per server, not per stripe).
        let segs = layout.map_range(data.len() as u64, 0, data.len() as u64);
        let mut per_server: Vec<Vec<u8>> = vec![Vec::new(); servers.max(1)];
        for seg in &segs {
            per_server[seg.server].extend_from_slice(
                &data[seg.object_offset as usize..(seg.object_offset + seg.len) as usize],
            );
        }

        let results: Vec<Result<()>> = {
            let paths: Vec<PathBuf> = (0..per_server.len())
                .map(|s| self.datafile(key, s))
                .collect();
            let payload: Vec<(PathBuf, Vec<u8>)> =
                paths.into_iter().zip(per_server).collect();
            let payload = Arc::new(payload);
            let p2 = Arc::clone(&payload);
            self.pool
                .map(payload.len(), move |i| {
                    let (path, bytes) = &p2[i];
                    fs::write(path, bytes).map_err(|e| Error::io(path, e))
                })
                .map_err(Error::Job)?
        };
        for r in results {
            r?;
        }

        // remove stale datafiles if the object previously spread wider
        for s in servers..self.server_dirs.len() {
            let p = self.datafile(key, s);
            let _ = fs::remove_file(p);
        }

        self.write_meta(
            key,
            &ObjectMeta {
                size: data.len() as u64,
                stripe,
                servers: servers.max(1),
                crc: checksum(data),
            },
        )?;
        self.bytes_written
            .fetch_add(data.len() as u64, Ordering::Relaxed);
        self.objects_written.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The layout geometry an object was stored with.
    pub fn object_layout(&self, key: &str) -> Result<(u64, StripeLayout)> {
        let meta = self.read_meta(key)?;
        Ok((meta.size, self.layout_of(&meta)?))
    }
}

#[derive(Debug, Clone, Copy)]
struct ObjectMeta {
    size: u64,
    stripe: u64,
    servers: usize,
    crc: u32,
}

impl ObjectMeta {
    fn parse(text: &str) -> Option<Self> {
        let mut size = None;
        let mut stripe = None;
        let mut servers = None;
        let mut crc = None;
        for line in text.lines() {
            let (k, v) = line.split_once('=')?;
            let v = v.trim();
            match k.trim() {
                "size" => size = v.parse().ok(),
                "stripe" => stripe = v.parse().ok(),
                "servers" => servers = v.parse().ok(),
                "crc" => crc = v.parse().ok(),
                _ => return None,
            }
        }
        Some(Self {
            size: size?,
            stripe: stripe?,
            servers: servers?,
            crc: crc?,
        })
    }
}

impl ObjectStore for Pfs {
    fn write(&self, key: &str, data: &[u8]) -> Result<()> {
        self.write_with_hints(key, data, Hints::default())
    }

    fn read(&self, key: &str) -> Result<Vec<u8>> {
        let meta = self.read_meta(key)?;
        let layout = self.layout_of(&meta)?;
        self.reads.fetch_add(1, Ordering::Relaxed);

        // Parallel full-datafile reads, then de-stripe.
        let servers = meta.servers;
        let paths: Vec<PathBuf> = (0..servers).map(|s| self.datafile(key, s)).collect();
        let paths = Arc::new(paths);
        let p2 = Arc::clone(&paths);
        let images: Vec<Result<Vec<u8>>> = self
            .pool
            .map(servers, move |s| {
                let path = &p2[s];
                if meta.size == 0 {
                    return Ok(Vec::new());
                }
                match fs::read(path) {
                    Ok(v) => Ok(v),
                    // a server with no stripes for a tiny object has no file
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
                    Err(e) => Err(Error::io(path, e)),
                }
            })
            .map_err(Error::Job)?;

        let mut out = vec![0u8; meta.size as usize];
        let mut cursors = vec![0usize; servers];
        let segs = layout.map_range(meta.size, 0, meta.size);
        for seg in segs {
            let img = match &images[seg.server] {
                Ok(v) => v,
                Err(e) => return Err(Error::Artifact(format!("server {} read: {e}", seg.server))),
            };
            let start = cursors[seg.server];
            let end = start + seg.len as usize;
            if end > img.len() {
                return Err(Error::Artifact(format!(
                    "truncated datafile for {key} on server {}",
                    seg.server
                )));
            }
            out[seg.object_offset as usize..(seg.object_offset + seg.len) as usize]
                .copy_from_slice(&img[start..end]);
            cursors[seg.server] = end;
        }

        if self.verify_reads {
            verify_checksum(key, &out, meta.crc)?;
        }
        self.bytes_read.fetch_add(meta.size, Ordering::Relaxed);
        Ok(out)
    }

    fn read_range(&self, key: &str, offset: u64, len: usize) -> Result<Vec<u8>> {
        let meta = self.read_meta(key)?;
        let layout = self.layout_of(&meta)?;
        self.reads.fetch_add(1, Ordering::Relaxed);
        let segs = layout.map_range(meta.size, offset, len as u64);
        let total: u64 = segs.iter().map(|s| s.len).sum();
        let mut out = vec![0u8; total as usize];
        let base = offset;

        // Group segments per server: one task per involved server opens
        // its datafile once and serves every segment it owns, so a range
        // spanning many stripes engages all data nodes concurrently
        // instead of seeking through them one stripe at a time.
        let mut per_server: Vec<Vec<StripeSegment>> =
            vec![Vec::new(); self.server_dirs.len()];
        for seg in &segs {
            per_server[seg.server].push(*seg);
        }
        let jobs: Vec<(PathBuf, Vec<StripeSegment>)> = per_server
            .into_iter()
            .enumerate()
            .filter(|(_, v)| !v.is_empty())
            .map(|(s, v)| (self.datafile(key, s), v))
            .collect();

        fn read_server(
            path: &Path,
            segs: &[StripeSegment],
            base: u64,
        ) -> Result<Vec<(usize, Vec<u8>)>> {
            let mut f = fs::File::open(path).map_err(|e| Error::io(path, e))?;
            let mut pieces = Vec::with_capacity(segs.len());
            for seg in segs {
                f.seek(SeekFrom::Start(seg.local_offset))
                    .map_err(|e| Error::io(path, e))?;
                let mut buf = vec![0u8; seg.len as usize];
                f.read_exact(&mut buf).map_err(|e| Error::io(path, e))?;
                pieces.push(((seg.object_offset - base) as usize, buf));
            }
            Ok(pieces)
        }

        if jobs.len() <= 1 {
            // Single-server fast path (e.g. a range within one stripe —
            // the common small two-level block read): no pool dispatch,
            // no temp buffers; read straight into the output.
            if let Some((path, segs)) = jobs.first() {
                let mut f = fs::File::open(path).map_err(|e| Error::io(path, e))?;
                for seg in segs {
                    f.seek(SeekFrom::Start(seg.local_offset))
                        .map_err(|e| Error::io(path, e))?;
                    let dst = (seg.object_offset - base) as usize;
                    f.read_exact(&mut out[dst..dst + seg.len as usize])
                        .map_err(|e| Error::io(path, e))?;
                }
            }
        } else {
            let jobs = Arc::new(jobs);
            let j2 = Arc::clone(&jobs);
            let results: Vec<Result<Vec<(usize, Vec<u8>)>>> = self
                .pool
                .map(jobs.len(), move |i| {
                    let (path, segs) = &j2[i];
                    read_server(path, segs, base)
                })
                .map_err(Error::Job)?;
            for r in results {
                for (dst_start, buf) in r? {
                    out[dst_start..dst_start + buf.len()].copy_from_slice(&buf);
                }
            }
        }
        self.bytes_read.fetch_add(total, Ordering::Relaxed);
        Ok(out)
    }

    fn size(&self, key: &str) -> Result<u64> {
        Ok(self.read_meta(key)?.size)
    }

    fn exists(&self, key: &str) -> bool {
        self.meta_path(key).exists()
    }

    fn delete(&self, key: &str) -> Result<()> {
        let _ = fs::remove_file(self.meta_path(key));
        for s in 0..self.server_dirs.len() {
            let _ = fs::remove_file(self.datafile(key, s));
        }
        Ok(())
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys = Vec::new();
        if let Ok(entries) = fs::read_dir(&self.meta_dir) {
            for e in entries.flatten() {
                let name = e.file_name().to_string_lossy().into_owned();
                if let Some(enc) = name.strip_suffix(".meta") {
                    let key = enc.replace("%2F", "/").replace("%25", "%");
                    if key.starts_with(prefix) {
                        keys.push(key);
                    }
                }
            }
        }
        keys.sort();
        keys
    }

    fn kind(&self) -> &'static str {
        "pfs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg32;

    fn rand_data(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Pcg32::new(seed, 1);
        let mut v = vec![0u8; n];
        rng.fill_bytes(&mut v);
        v
    }

    fn open(dir: &TempDir, servers: usize, stripe: u64) -> Pfs {
        Pfs::open(dir.path(), servers, stripe).unwrap()
    }

    #[test]
    fn roundtrip_various_sizes() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 64);
        for (i, n) in [0usize, 1, 63, 64, 65, 128, 1000, 10_000].iter().enumerate() {
            let key = format!("obj{i}");
            let data = rand_data(*n, i as u64);
            pfs.write(&key, &data).unwrap();
            assert_eq!(pfs.read(&key).unwrap(), data, "size {n}");
            assert_eq!(pfs.size(&key).unwrap(), *n as u64);
        }
    }

    #[test]
    fn stripes_actually_distributed() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 4, 32);
        pfs.write("spread", &rand_data(256, 7)).unwrap();
        // each server holds a 64-byte datafile (2 stripes of 32)
        for s in 0..4 {
            let df = dir.path().join(format!("server{s}")).join("spread.df");
            assert_eq!(fs::metadata(df).unwrap().len(), 64, "server {s}");
        }
    }

    #[test]
    fn read_range_matches_slice() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 50);
        let data = rand_data(1000, 9);
        pfs.write("r", &data).unwrap();
        for (off, len) in [(0usize, 1000usize), (0, 10), (45, 10), (999, 1), (990, 100), (1000, 5)] {
            let got = pfs.read_range("r", off as u64, len).unwrap();
            let end = (off + len).min(1000);
            assert_eq!(got, &data[off.min(1000)..end], "off={off} len={len}");
        }
    }

    #[test]
    fn hints_override_layout() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 4, 64);
        let data = rand_data(512, 3);
        pfs.write_with_hints(
            "hinted",
            &data,
            Hints {
                stripe_size: Some(128),
                servers: Some(2),
            },
        )
        .unwrap();
        let (size, layout) = pfs.object_layout("hinted").unwrap();
        assert_eq!(size, 512);
        assert_eq!(layout.stripe_size, 128);
        assert_eq!(layout.servers, 2);
        assert_eq!(pfs.read("hinted").unwrap(), data);
        // servers 2..4 must hold nothing
        assert!(!dir.path().join("server2").join("hinted.df").exists());
    }

    #[test]
    fn rewrite_shrinks_cleanly() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 16);
        pfs.write("k", &rand_data(160, 1)).unwrap();
        let small = rand_data(8, 2);
        pfs.write("k", &small).unwrap();
        assert_eq!(pfs.read("k").unwrap(), small);
        assert_eq!(pfs.size("k").unwrap(), 8);
    }

    #[test]
    fn corruption_detected_on_read() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("c", &rand_data(100, 5)).unwrap();
        // flip a byte in server0's datafile
        let df = dir.path().join("server0").join("c.df");
        let mut bytes = fs::read(&df).unwrap();
        bytes[0] ^= 0xFF;
        fs::write(&df, bytes).unwrap();
        let err = pfs.read("c").unwrap_err();
        assert!(matches!(err, Error::ChecksumMismatch { .. }), "{err}");
    }

    #[test]
    fn missing_object_is_not_found() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        assert!(matches!(pfs.read("ghost"), Err(Error::NotFound(_))));
        assert!(!pfs.exists("ghost"));
    }

    #[test]
    fn delete_is_idempotent_and_complete() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("d", &rand_data(100, 6)).unwrap();
        pfs.delete("d").unwrap();
        pfs.delete("d").unwrap();
        assert!(!pfs.exists("d"));
        assert!(!dir.path().join("server0").join("d.df").exists());
        assert!(!dir.path().join("server1").join("d.df").exists());
    }

    #[test]
    fn list_decodes_slashed_keys() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("in/part-0", b"a").unwrap();
        pfs.write("in/part-1", b"b").unwrap();
        pfs.write("out/part-0", b"c").unwrap();
        assert_eq!(pfs.list("in/"), vec!["in/part-0", "in/part-1"]);
        assert_eq!(pfs.list(""), vec!["in/part-0", "in/part-1", "out/part-0"]);
    }

    #[test]
    fn percent_keys_roundtrip() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("we%ird/na%2Fme", b"x").unwrap();
        assert_eq!(pfs.list("we%"), vec!["we%ird/na%2Fme"]);
        assert_eq!(pfs.read("we%ird/na%2Fme").unwrap(), b"x");
    }

    #[test]
    fn stats_accumulate() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 2, 32);
        pfs.write("s", &rand_data(100, 8)).unwrap();
        let _ = pfs.read("s").unwrap();
        let _ = pfs.read_range("s", 0, 10).unwrap();
        let st = pfs.stats();
        assert_eq!(st.bytes_written, 100);
        assert_eq!(st.bytes_read, 110);
        assert_eq!(st.objects_written, 1);
        assert_eq!(st.reads, 2);
    }

    #[test]
    fn concurrent_range_reads_are_consistent() {
        let dir = TempDir::new("pfs-conc").unwrap();
        let pfs = Arc::new(open(&dir, 4, 64));
        let data = rand_data(64 * 41, 11); // odd stripe count over 4 servers
        pfs.write("wide", &data).unwrap();
        std::thread::scope(|s| {
            for t in 0..4usize {
                let pfs = Arc::clone(&pfs);
                let data = &data;
                s.spawn(move || {
                    for i in 0..20 {
                        let off = (t * 97 + i * 131) % data.len();
                        let len = 777.min(data.len() - off);
                        assert_eq!(
                            pfs.read_range("wide", off as u64, len).unwrap(),
                            &data[off..off + len],
                            "t={t} off={off}"
                        );
                    }
                });
            }
        });
    }

    #[test]
    fn empty_object_roundtrip() {
        let dir = TempDir::new("pfs").unwrap();
        let pfs = open(&dir, 3, 64);
        pfs.write("empty", b"").unwrap();
        assert_eq!(pfs.read("empty").unwrap(), Vec::<u8>::new());
        assert!(pfs.exists("empty"));
    }
}
