//! Property tests on coordinator invariants (routing, batching, state):
//!
//! - router: every read returns correct bytes regardless of residency;
//!   mem_reads + pfs_reads == total reads
//! - checkpointer: after flush, every enqueued object is persisted and
//!   the dirty namespace is empty; backlog never exceeds max_pending
//! - partitioner (TeraSort routing): monotone over the key space and
//!   covers all partitions for balanced histograms
//! - scheduler: every split assigned exactly once; load spread ≤ ceil

use std::sync::Arc;

use tlstore::coordinator::{CheckpointerConfig, Coordinator};
use tlstore::mapreduce::{InputSplit, LocalityScheduler};
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, ReadMode, WriteMode};
use tlstore::terasort::Partitioner;
use tlstore::testing::{proprun, PropConfig, TempDir};

fn cfg(cases: u32, max_size: usize) -> PropConfig {
    PropConfig {
        cases,
        max_size,
        ..Default::default()
    }
}

fn mk_store(dir: &TempDir) -> Arc<TwoLevelStore> {
    Arc::new(
        TwoLevelStore::open(
            TlsConfig::builder(dir.path())
                .mem_capacity(96 << 10)
                .block_size(16 << 10)
                .pfs_servers(2)
                .stripe_size(8 << 10)
                .build()
                .unwrap(),
        )
        .unwrap(),
    )
}

#[test]
fn prop_router_counts_and_correctness() {
    let dir = TempDir::new("prop-router").unwrap();
    let store = mk_store(&dir);
    let coord = Coordinator::new(Arc::clone(&store), CheckpointerConfig::default());
    let counter = std::sync::atomic::AtomicU64::new(0);
    proprun(
        "router",
        cfg(40, 32),
        |rng, size| {
            let n = rng.gen_range((size * 4096) as u32 + 1) as usize;
            let mut v = vec![0u8; n];
            rng.fill_bytes(&mut v);
            let evict = rng.gen_range(2) == 0;
            (v, evict)
        },
        |(data, evict)| {
            let key = format!(
                "r{}",
                counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            );
            let before = coord.router().stats();
            coord.write_sync(&key, data).map_err(|e| format!("{e}"))?;
            if *evict {
                store.evict_object(&key).map_err(|e| format!("{e}"))?;
            }
            let got = coord.read(&key).map_err(|e| format!("{e}"))?;
            if got != *data {
                return Err("router returned wrong bytes".into());
            }
            let after = coord.router().stats();
            let total = (after.mem_reads - before.mem_reads) + (after.pfs_reads - before.pfs_reads);
            if total != 1 {
                return Err(format!("read counted {total} times"));
            }
            if after.bytes - before.bytes != data.len() as u64 {
                return Err("byte accounting off".into());
            }
            Ok(())
        },
    );
    coord.shutdown().unwrap();
}

#[test]
fn prop_checkpointer_flush_persists_everything() {
    proprun(
        "checkpointer",
        cfg(12, 16),
        |rng, size| {
            let objects: Vec<usize> = (0..size.max(1))
                .map(|_| rng.gen_range(40_000) as usize + 1)
                .collect();
            let max_pending = rng.gen_range(6) as usize + 1;
            (objects, max_pending)
        },
        |(objects, max_pending)| {
            let dir = TempDir::new("prop-ckpt").unwrap();
            let store = mk_store(&dir);
            let coord = Coordinator::new(
                Arc::clone(&store),
                CheckpointerConfig {
                    max_pending: *max_pending,
                    ..Default::default()
                },
            );
            for (i, n) in objects.iter().enumerate() {
                coord
                    .write_async(&format!("o{i}"), &vec![(i % 251) as u8; *n])
                    .map_err(|e| format!("{e}"))?;
                if coord.checkpointer().backlog() > *max_pending {
                    return Err("backlog exceeded max_pending".into());
                }
            }
            coord.flush().map_err(|e| format!("{e}"))?;
            if !store.unpersisted().is_empty() {
                return Err(format!("unpersisted after flush: {:?}", store.unpersisted()));
            }
            if !store.pfs().list(".dirty/").is_empty() {
                return Err("dirty namespace not drained".into());
            }
            for (i, n) in objects.iter().enumerate() {
                let got = store
                    .read(&format!("o{i}"), ReadMode::Bypass)
                    .map_err(|e| format!("{e}"))?;
                if got != vec![(i % 251) as u8; *n] {
                    return Err(format!("object o{i} corrupted"));
                }
            }
            coord.shutdown().map_err(|e| format!("{e}"))?;
            Ok(())
        },
    );
}

#[test]
fn prop_partitioner_monotone_and_complete() {
    proprun(
        "partitioner",
        cfg(100, 64),
        |rng, _size| {
            let parts = rng.gen_range(255) + 1;
            let mut hist = [0i64; 256];
            for h in hist.iter_mut() {
                *h = rng.gen_range(1000) as i64;
            }
            (parts, hist)
        },
        |&(parts, hist)| {
            let p = Partitioner::from_histogram(&hist, parts);
            if !p.is_monotone() {
                return Err("not monotone".into());
            }
            // first bucket → partition 0; last bucket → last partition may
            // be unused for skewed data, but never out of range
            if p.partition_of(0) != 0 && hist[0] > 0 {
                return Err("bucket 0 not in partition 0".into());
            }
            // keys in the same bucket always agree
            for b in [0u32, 17, 255] {
                let lo = b << 24;
                let hi = (b << 24) | 0x00FF_FFFF;
                if p.partition_of(lo) != p.partition_of(hi) {
                    return Err(format!("bucket {b} split across partitions"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_assigns_each_split_once_balanced() {
    proprun(
        "scheduler",
        cfg(100, 64),
        |rng, size| {
            let nodes = rng.gen_range(12) as usize + 1;
            let splits: Vec<Option<usize>> = (0..size * 3)
                .map(|_| {
                    if rng.gen_range(4) == 0 {
                        None
                    } else {
                        Some(rng.gen_range(16) as usize)
                    }
                })
                .collect();
            (nodes, splits)
        },
        |(nodes, prefs)| {
            let splits: Vec<InputSplit> = prefs
                .iter()
                .map(|p| InputSplit {
                    object: "o".into(),
                    offset: 0,
                    len: 1,
                    preferred_node: *p,
                })
                .collect();
            let sched = LocalityScheduler::new(*nodes, 4);
            let (assigns, hits) = sched.assign(&splits);
            if assigns.len() != splits.len() {
                return Err("missing assignments".into());
            }
            let mut seen = vec![false; splits.len()];
            let mut load = vec![0usize; *nodes];
            for a in &assigns {
                if seen[a.split] {
                    return Err(format!("split {} assigned twice", a.split));
                }
                seen[a.split] = true;
                if a.node >= *nodes {
                    return Err("node out of range".into());
                }
                load[a.node] += 1;
                if a.local && splits[a.split].preferred_node.map(|p| p % nodes) != Some(a.node) {
                    return Err("local flag on non-preferred node".into());
                }
            }
            if hits > splits.len() {
                return Err("hits exceed splits".into());
            }
            let cap = splits.len().div_ceil(*nodes);
            if load.iter().any(|&l| l > cap) {
                return Err(format!("node over balanced cap: {load:?} cap {cap}"));
            }
            Ok(())
        },
    );
}
