//! Consistency: the discrete-event simulator (`sim`) and the analytic
//! models (`model`) evaluated on the *same* cluster constants must agree
//! — otherwise one of them can drift unnoticed and Figure-5/Figure-7
//! claims stop meaning anything.
//!
//! The case table lives in `testing::parity::sim_model_cases` and is the
//! **same** table `tlstore bench parity` renders into `BENCH_fig5.json`
//! and gates on, so this suite and the CLI gate cannot diverge. Each
//! case drives an I/O-only task set through the simulator on the §5.1
//! testbed geometry (N=16, M=2, the Palmetto constants both modules
//! share) and compares the per-node throughput against the closed-form
//! `q`, with per-case tolerances (flows that fan in across nodes —
//! HDFS's replicated write — accumulate more discretization error than
//! the clean striped paths).

use tlstore::model::ClusterParams;
use tlstore::sim::{BackendKind, SimConstants};
use tlstore::testing::parity::{sim_model_cases, sim_per_node_mbs};

#[test]
fn every_shared_case_agrees_within_its_tolerance() {
    let cases = sim_model_cases().unwrap();
    // the table covers every equation family: reads and writes for OFS,
    // TLS, and HDFS
    let names: Vec<&str> = cases.iter().map(|c| c.name).collect();
    for expect in [
        "ofs_read",
        "ofs_write",
        "tls_read_f0.5",
        "tls_write",
        "hdfs_read_local",
        "hdfs_write_durable",
    ] {
        assert!(names.contains(&expect), "case table lost `{expect}`: {names:?}");
    }
    for c in &cases {
        assert!(
            c.within(),
            "{}: sim {:.2} MB/s vs model {:.2} MB/s (rel err {:.3} > {})",
            c.name,
            c.sim_mbs,
            c.model_mbs,
            c.rel_err(),
            c.tolerance
        );
        assert!(c.sim_mbs > 0.0 && c.model_mbs > 0.0, "{}: degenerate case", c.name);
    }
}

#[test]
fn sim_matches_eq7_across_more_residencies() {
    // beyond the shared table's f=0.5 point: the harmonic-mean curve
    // holds across the residency range
    let p = ClusterParams::palmetto();
    for (f_pct, f) in [(25u8, 0.25f64), (80, 0.8)] {
        let sim = sim_per_node_mbs(SimConstants::default(), |c, i, d| {
            c.read_flows(BackendKind::Tls { f_pct }, i, d)
        })
        .unwrap();
        let model = p.tls_read(f);
        let err = (sim - model).abs() / model;
        assert!(
            err <= 0.10,
            "tls read f={f}: sim {sim:.2} MB/s vs model {model:.2} MB/s (rel err {err:.3})"
        );
    }
}

#[test]
fn sim_and_model_share_their_constants() {
    // the agreement above is only meaningful if both sides really run on
    // the same numbers — pin the linkage
    let p = ClusterParams::palmetto();
    let c = SimConstants::default();
    assert_eq!(p.nu, c.ram_mbs);
    assert_eq!(p.rho, c.nic_mbs);
    assert_eq!(p.mu_read, c.disk_mbs);
    assert_eq!(p.mu_p_read, c.raid_read_mbs);
    assert_eq!(p.mu_p_write, c.raid_write_mbs);
    assert_eq!(p.phi, c.backplane_mbs);
}
