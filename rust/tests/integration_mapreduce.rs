//! Integration: TeraGen → TeraSort → TeraValidate through the Job API
//! (JobServer + spilled shuffle), the real storage backends, and the
//! block-sort kernel.
//!
//! The sort kernel is chosen per environment: the PJRT artifact when
//! `artifacts/` is built, the portable CPU sort otherwise — so this
//! suite runs everywhere instead of skipping (`SortKernel::auto`).

#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::path::Path;
use std::sync::{Arc, OnceLock};

use tlstore::config::Backend;
use tlstore::mapreduce::{JobServer, JobServerConfig};
use tlstore::storage::hdfs::HdfsLike;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::ObjectStore;
use tlstore::terasort::{
    input_checksum, run_terasort, teragen, teravalidate, Partitioner, SortKernel, RECORD_SIZE,
};
use tlstore::testing::TempDir;

fn kernel() -> Arc<SortKernel> {
    static K: OnceLock<Arc<SortKernel>> = OnceLock::new();
    K.get_or_init(|| {
        let k = SortKernel::auto(Path::new("artifacts"));
        eprintln!("terasort integration: sort kernel = {}", k.name());
        k
    })
    .clone()
}

fn tls_store(dir: &TempDir) -> Arc<dyn ObjectStore> {
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(32 << 20)
        .block_size(1 << 20)
        .pfs_servers(2)
        .stripe_size(256 << 10)
        .build()
        .unwrap();
    Arc::new(TwoLevelStore::open(cfg).unwrap())
}

fn backend_store(backend: Backend, dir: &TempDir) -> Arc<dyn ObjectStore> {
    match backend {
        Backend::TwoLevel => tls_store(dir),
        Backend::Pfs => Arc::new(Pfs::open(dir.path(), 2, 256 << 10).unwrap()),
        Backend::Hdfs => Arc::new(HdfsLike::open(dir.path(), 4, 3).unwrap()),
    }
}

fn server(store: Arc<dyn ObjectStore>) -> JobServer {
    JobServer::new(
        store,
        JobServerConfig {
            workers: 4,
            nodes: 4,
            containers_per_node: 4,
            max_concurrent_jobs: 1,
            ..JobServerConfig::default()
        },
    )
}

fn terasort_roundtrip(backend: Backend, records: u64, reducers: u32) {
    let dir = TempDir::new(&format!("ts-{}", backend.name())).unwrap();
    let store = backend_store(backend, &dir);

    let written = teragen(store.as_ref(), "in/", records, records / 3 + 1, 42).unwrap();
    assert_eq!(written, records * RECORD_SIZE as u64);
    let (in_count, in_sum) = input_checksum(store.as_ref(), "in/").unwrap();
    assert_eq!(in_count, records);

    let srv = server(Arc::clone(&store));
    let stats = run_terasort(&srv, kernel(), "in/", "out/", reducers, 64 << 10, true).unwrap();
    srv.shutdown().unwrap();
    assert_eq!(stats.shuffle_records(), records);
    assert_eq!(stats.input_bytes(), written);
    assert_eq!(stats.output_bytes(), written);
    // TeraSort rides the spilled-shuffle dataflow plane now: runs went
    // through `.shuffle/` objects and were cleaned up afterwards
    assert!(stats.spilled_runs() > 0, "{backend:?}: shuffle must spill");
    assert!(
        store.list(tlstore::storage::SHUFFLE_NS).is_empty(),
        "{backend:?}: shuffle namespace must be clean"
    );
    // measured I/O instrumentation is present and consistent
    let read = stats.map_read_io();
    assert_eq!(read.bytes, written, "{backend:?}: read bytes");
    assert!(read.mbs() > 0.0);
    assert_eq!(stats.reduce_write_io().bytes, written, "{backend:?}: write bytes");

    let report = teravalidate(store.as_ref(), "out/").unwrap();
    assert!(report.sorted, "{backend:?}: output must be globally sorted");
    assert_eq!(report.records, records, "{backend:?}: record count");
    assert_eq!(report.checksum, in_sum, "{backend:?}: checksum must match");
}

#[test]
fn terasort_on_two_level_store() {
    terasort_roundtrip(Backend::TwoLevel, 10_000, 4);
}

#[test]
fn terasort_on_pfs_only() {
    terasort_roundtrip(Backend::Pfs, 6_000, 3);
}

#[test]
fn terasort_on_hdfs_like() {
    terasort_roundtrip(Backend::Hdfs, 6_000, 3);
}

#[test]
fn terasort_single_reducer_and_tiny_input() {
    terasort_roundtrip(Backend::TwoLevel, 17, 1);
}

#[test]
fn terasort_more_reducers_than_buckets_with_data() {
    terasort_roundtrip(Backend::TwoLevel, 2_000, 16);
}

#[test]
fn sampled_partitioner_is_monotone_on_real_data() {
    let dir = TempDir::new("ts-part").unwrap();
    let store = tls_store(&dir);
    teragen(store.as_ref(), "in/", 5_000, 2_000, 7).unwrap();
    let p =
        tlstore::terasort::sample_partitioner(store.as_ref(), "in/", &kernel(), 8, 4).unwrap();
    assert!(p.is_monotone());
    // uniform data → partitions should all receive some buckets
    let hits: std::collections::HashSet<u32> =
        (0..=255u32).map(|b| p.partition_of(b << 24)).collect();
    assert!(hits.len() >= 7, "expected near-all partitions used, got {hits:?}");
    let _ = Partitioner::uniform(8);
}

#[test]
fn teragen_is_deterministic_across_stores() {
    let dir1 = TempDir::new("tg1").unwrap();
    let dir2 = TempDir::new("tg2").unwrap();
    let s1 = backend_store(Backend::Pfs, &dir1);
    let s2 = backend_store(Backend::Hdfs, &dir2);
    teragen(s1.as_ref(), "in/", 1000, 300, 99).unwrap();
    teragen(s2.as_ref(), "in/", 1000, 300, 99).unwrap();
    let (c1, sum1) = input_checksum(s1.as_ref(), "in/").unwrap();
    let (c2, sum2) = input_checksum(s2.as_ref(), "in/").unwrap();
    assert_eq!((c1, sum1), (c2, sum2));
}
