//! End-to-end Job API v2 suite: the two built-in multi-stage workloads
//! through a [`JobServer`] over a real two-level store, plus the
//! concurrency contracts — shuffle demonstrably flowing through
//! `.shuffle/` objects (asserted via a probing store wrapper, not logs),
//! concurrent jobs isolated from each other, admission queueing, and
//! cancellation leaving zero shuffle residue.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use tlstore::error::Result;
use tlstore::mapreduce::{
    InputSplit, JobServer, JobServerConfig, JobStatus, MapContext, Mapper, MergeIter,
    PipelineSpec, Reducer, KV,
};
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectMeta, ObjectReader, ObjectStore, ObjectWriter, SHUFFLE_NS};
use tlstore::testing::TempDir;
use tlstore::workloads::{sessions, wordcount, NamedWorkload};

fn tls(dir: &TempDir) -> Arc<TwoLevelStore> {
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(8 << 20) // small: shuffle traffic exercises eviction
        .block_size(64 << 10)
        .pfs_servers(3)
        .stripe_size(16 << 10)
        .build()
        .unwrap();
    Arc::new(TwoLevelStore::open(cfg).unwrap())
}

fn server(store: Arc<dyn ObjectStore>, max_jobs: usize) -> JobServer {
    JobServer::new(
        store,
        JobServerConfig {
            workers: 4,
            nodes: 2,
            containers_per_node: 2,
            max_concurrent_jobs: max_jobs,
            shuffle_spill_threshold: 0,
            shuffle_chunk: 4 << 10, // small windows: many read_at refills
            overlap_depth: 1, // prefetch + priming under the full server
            split_buffer: 1 << 16,
            cluster_epoch: 0,
        },
    )
}

/// Store wrapper recording every created key — the conformance probe
/// proving shuffle data flowed through `.shuffle/` objects.
struct Probe<S> {
    inner: S,
    created: Mutex<Vec<String>>,
}

impl<S> Probe<S> {
    fn new(inner: S) -> Self {
        Self {
            inner,
            created: Mutex::new(Vec::new()),
        }
    }

    fn created_under(&self, prefix: &str) -> usize {
        self.created
            .lock()
            .unwrap()
            .iter()
            .filter(|k| k.starts_with(prefix))
            .count()
    }
}

impl<S: ObjectStore> ObjectStore for Probe<S> {
    fn open(&self, key: &str) -> Result<Box<dyn ObjectReader + '_>> {
        self.inner.open(key)
    }
    fn create(&self, key: &str) -> Result<Box<dyn ObjectWriter + '_>> {
        self.created.lock().unwrap().push(key.to_string());
        self.inner.create(key)
    }
    fn stat(&self, key: &str) -> Result<ObjectMeta> {
        self.inner.stat(key)
    }
    fn delete(&self, key: &str) -> Result<()> {
        self.inner.delete(key)
    }
    fn list(&self, prefix: &str) -> Vec<String> {
        self.inner.list(prefix)
    }
    fn kind(&self) -> &'static str {
        "probe"
    }
}

#[test]
fn wordcount_topk_end_to_end_with_shuffle_conformance() {
    let dir = TempDir::new("jobv2-wc").unwrap();
    let probe = Arc::new(Probe::new(tls(&dir)));
    let store: Arc<dyn ObjectStore> = Arc::clone(&probe) as Arc<dyn ObjectStore>;

    wordcount::generate_text(store.as_ref(), "wc/in/", 4, 800, 11).unwrap();
    let srv = server(Arc::clone(&store), 2);
    let spec = wordcount::pipeline("wc/in/", "wc/out/", 3, 8).unwrap();
    let handle = srv.submit(spec).unwrap();
    let stats = handle.join().unwrap();

    // conformance: the shuffle *provably* rode the store — spill objects
    // were created under this job's .shuffle/ namespace (both rounds plus
    // the intermediate round-1 output), and the stats agree
    let job_ns = format!("{SHUFFLE_NS}{}/", handle.id());
    assert!(
        probe.created_under(&job_ns) > 0,
        "no objects created under {job_ns}"
    );
    assert!(probe.created_under(&format!("{job_ns}s0/")) > 0, "round-0 spills");
    assert!(probe.created_under(&format!("{job_ns}s1/")) > 0, "round-1 spills");
    assert!(probe.created_under(&format!("{job_ns}inter-1/")) > 0, "intermediate outputs");
    assert!(stats.spilled_runs() > 0);
    assert!(stats.spilled_bytes() > 0);
    assert_eq!(stats.stages.len(), 4, "two full rounds");

    // ...and was cleaned up afterwards
    assert!(store.list(SHUFFLE_NS).is_empty(), "shuffle residue after success");

    // results verified against ground truth recomputed from the input
    let summary = wordcount::verify_topk(store.as_ref(), "wc/in/", "wc/out/").unwrap();
    assert!(summary.contains("ok"), "{summary}");
    srv.shutdown().unwrap();
}

#[test]
fn log_sessions_end_to_end() {
    let dir = TempDir::new("jobv2-sessions").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    sessions::generate_logs(store.as_ref(), "sess/in/", 12, 48, 23).unwrap();
    let srv = server(Arc::clone(&store), 2);
    let handle = srv.submit(sessions::pipeline("sess/in/", "sess/out/", 3).unwrap()).unwrap();
    let stats = handle.join().unwrap();
    assert!(stats.spilled_runs() > 0);
    let summary = sessions::verify_histogram(store.as_ref(), "sess/in/", "sess/out/").unwrap();
    assert!(summary.contains("histogram ok"), "{summary}");
    assert!(store.list(SHUFFLE_NS).is_empty());
    srv.shutdown().unwrap();
}

#[test]
fn named_workload_registry_runs_both() {
    // the CLI path: generate → pipeline → verify, by name
    for w in NamedWorkload::all() {
        let dir = TempDir::new(&format!("jobv2-named-{}", w.name())).unwrap();
        let store: Arc<dyn ObjectStore> = tls(&dir);
        let root = format!("{}/", w.name());
        w.generate(store.as_ref(), &root, 4, 5).unwrap();
        let srv = server(Arc::clone(&store), 1);
        let stats = srv.submit(w.pipeline(&root, 2).unwrap()).unwrap().join().unwrap();
        assert!(stats.spilled_runs() > 0, "{}", w.name());
        w.verify(store.as_ref(), &root).unwrap();
        srv.shutdown().unwrap();
    }
}

#[test]
fn concurrent_jobs_do_not_crosstalk() {
    // two different pipelines, one server, overlapping execution: each
    // job's outputs must verify against its own input, and nothing may
    // leak across namespaces
    let dir = TempDir::new("jobv2-concurrent").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    wordcount::generate_text(store.as_ref(), "a/in/", 4, 600, 31).unwrap();
    sessions::generate_logs(store.as_ref(), "b/in/", 10, 40, 37).unwrap();

    let srv = server(Arc::clone(&store), 2);
    let wc = srv.submit(wordcount::pipeline("a/in/", "a/out/", 3, 6).unwrap()).unwrap();
    let se = srv.submit(sessions::pipeline("b/in/", "b/out/", 2).unwrap()).unwrap();
    assert_ne!(wc.id(), se.id(), "distinct job namespaces");

    let wc_stats = wc.join().unwrap();
    let se_stats = se.join().unwrap();
    assert!(wc_stats.spilled_runs() > 0);
    assert!(se_stats.spilled_runs() > 0);
    wordcount::verify_topk(store.as_ref(), "a/in/", "a/out/").unwrap();
    sessions::verify_histogram(store.as_ref(), "b/in/", "b/out/").unwrap();
    // isolation: each output namespace holds exactly its own partitions
    assert_eq!(store.list("a/out/").len(), 1);
    assert_eq!(store.list("b/out/").len(), 1);
    assert!(store.list(SHUFFLE_NS).is_empty());
    srv.shutdown().unwrap();
}

// ---- gated jobs: deterministic queueing/cancel tests -------------------

/// A mapper that parks until its gate opens (so tests control exactly
/// when a job can make progress).
#[derive(Clone)]
struct Gate(Arc<(Mutex<bool>, Condvar)>);

impl Gate {
    fn new() -> Self {
        Gate(Arc::new((Mutex::new(false), Condvar::new())))
    }
    fn open(&self) {
        let (lock, cond) = &*self.0;
        *lock.lock().unwrap() = true;
        cond.notify_all();
    }
    fn wait_open(&self) {
        let (lock, cond) = &*self.0;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cond.wait(open).unwrap();
        }
    }
}

struct GatedMapper {
    gate: Gate,
    entered: Arc<AtomicUsize>,
}

impl Mapper for GatedMapper {
    fn map(&self, _s: &InputSplit, data: &[u8], ctx: &mut MapContext) -> Result<()> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        self.gate.wait_open();
        ctx.emit(0, KV::new(b"k", data));
        Ok(())
    }
}

struct NullReducer;
impl Reducer for NullReducer {
    fn reduce(&self, _p: u32, records: MergeIter<'_>, out: &mut Vec<u8>) -> Result<()> {
        out.extend_from_slice(&(records.count() as u64).to_le_bytes());
        Ok(())
    }
}

fn gated_spec(name: &str, input: &str, output: &str, gate: &Gate, entered: &Arc<AtomicUsize>) -> PipelineSpec {
    PipelineSpec::builder(name)
        .input(input)
        .output(output)
        .map(Arc::new(GatedMapper {
            gate: gate.clone(),
            entered: Arc::clone(entered),
        }))
        .reduce(Arc::new(NullReducer), 1)
        .build()
        .unwrap()
}

fn wait_for<F: Fn() -> bool>(what: &str, f: F) {
    for _ in 0..500 {
        if f() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

#[test]
fn admission_queues_beyond_max_concurrent_jobs() {
    let dir = TempDir::new("jobv2-admission").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    store.write("g/in/x", b"payload").unwrap();

    let srv = server(Arc::clone(&store), 1);
    let gate_a = Gate::new();
    let entered_a = Arc::new(AtomicUsize::new(0));
    let a = srv.submit(gated_spec("job-a", "g/in/", "g/a/", &gate_a, &entered_a)).unwrap();
    // A is admitted and parked inside its map task
    wait_for("job A to start mapping", || entered_a.load(Ordering::SeqCst) > 0);
    assert_eq!(a.status(), JobStatus::Running);
    assert_eq!(srv.running(), 1);
    let (used, capacity) = srv.container_usage();
    assert!(used >= 1 && used <= capacity, "{used}/{capacity}");

    // B must queue behind max_concurrent_jobs = 1
    let gate_b = Gate::new();
    let entered_b = Arc::new(AtomicUsize::new(0));
    let b = srv.submit(gated_spec("job-b", "g/in/", "g/b/", &gate_b, &entered_b)).unwrap();
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(b.status(), JobStatus::Queued, "B admitted past the limit");
    assert_eq!(entered_b.load(Ordering::SeqCst), 0);

    // release A → B is admitted and completes
    gate_b.open(); // so B can run once admitted
    gate_a.open();
    a.join().unwrap();
    b.join().unwrap();
    assert!(store.exists("g/a/part-r-00000"));
    assert!(store.exists("g/b/part-r-00000"));
    assert!(store.list(SHUFFLE_NS).is_empty());
    srv.shutdown().unwrap();
}

#[test]
fn cancel_running_job_leaves_no_shuffle_residue() {
    let dir = TempDir::new("jobv2-cancel").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    // several input objects → several map tasks; the first ones park
    for i in 0..4 {
        store.write(&format!("c/in/{i}"), b"data data data").unwrap();
    }
    let srv = server(Arc::clone(&store), 1);
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let h = srv.submit(gated_spec("doomed", "c/in/", "c/out/", &gate, &entered)).unwrap();
    wait_for("job to start mapping", || entered.load(Ordering::SeqCst) > 0);

    h.cancel();
    gate.open(); // unblock the parked tasks; later tasks see the flag
    let err = h.join().unwrap_err();
    assert!(matches!(err, tlstore::Error::Canceled(_)), "{err}");
    assert_eq!(h.status(), JobStatus::Canceled);
    assert!(h.stats().is_none());
    assert!(store.list(SHUFFLE_NS).is_empty(), "canceled job left shuffle residue");
    assert!(store.list("c/out/").is_empty(), "canceled job published output");
    srv.shutdown().unwrap();
}

#[test]
fn cancel_queued_job_never_runs() {
    let dir = TempDir::new("jobv2-cancel-queued").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    store.write("q/in/x", b"payload").unwrap();
    let srv = server(Arc::clone(&store), 1);
    let gate_a = Gate::new();
    let entered_a = Arc::new(AtomicUsize::new(0));
    let a = srv.submit(gated_spec("holder", "q/in/", "q/a/", &gate_a, &entered_a)).unwrap();
    wait_for("holder to start", || entered_a.load(Ordering::SeqCst) > 0);

    let gate_b = Gate::new();
    let entered_b = Arc::new(AtomicUsize::new(0));
    let b = srv.submit(gated_spec("victim", "q/in/", "q/b/", &gate_b, &entered_b)).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    b.cancel();
    let err = b.join().unwrap_err();
    assert!(matches!(err, tlstore::Error::Canceled(_)), "{err}");
    assert_eq!(entered_b.load(Ordering::SeqCst), 0, "queued victim must never map");

    gate_a.open();
    a.join().unwrap();
    assert!(store.list("q/b/").is_empty());
    srv.shutdown().unwrap();
}

/// A mapper that emits every word, so the job actually spills.
struct EmitMapper;
impl Mapper for EmitMapper {
    fn map(&self, _s: &InputSplit, data: &[u8], ctx: &mut MapContext) -> Result<()> {
        for w in data.split(|b| b.is_ascii_whitespace()).filter(|w| !w.is_empty()) {
            ctx.emit(0, KV::new(w, b""));
        }
        Ok(())
    }
}

/// A reducer that parks on its gate *after* the map phase spilled, so a
/// test can hold a job mid-flight with live `.shuffle/` objects.
struct GatedReducer {
    gate: Gate,
    entered: Arc<AtomicUsize>,
}
impl Reducer for GatedReducer {
    fn reduce(&self, _p: u32, records: MergeIter<'_>, out: &mut Vec<u8>) -> Result<()> {
        self.entered.fetch_add(1, Ordering::SeqCst);
        self.gate.wait_open();
        out.extend((records.count() as u64).to_le_bytes());
        Ok(())
    }
}

#[test]
fn shutdown_reaps_only_its_own_jobs() {
    // two servers over ONE store (the Engine adapter spawns a transient
    // server per run, so this shape is normal): shutting server A down
    // must not delete server B's live in-flight spills
    let dir = TempDir::new("jobv2-two-servers").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    store.write("b/in/x", b"alpha beta gamma").unwrap();
    wordcount::generate_text(store.as_ref(), "a/in/", 2, 200, 41).unwrap();

    // B: parked in its reduce phase, spills alive on the store
    let srv_b = server(Arc::clone(&store), 1);
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let spec_b = PipelineSpec::builder("parked")
        .input("b/in/")
        .output("b/out/")
        .map(Arc::new(EmitMapper))
        .reduce(
            Arc::new(GatedReducer {
                gate: gate.clone(),
                entered: Arc::clone(&entered),
            }),
            1,
        )
        .build()
        .unwrap();
    let b = srv_b.submit(spec_b).unwrap();
    wait_for("B to reach its reducer", || entered.load(Ordering::SeqCst) > 0);
    let b_ns = format!("{SHUFFLE_NS}{}/", b.id());
    assert!(!store.list(&b_ns).is_empty(), "B must have live spills");

    // A: run a full job on its own server, then shut that server down
    let srv_a = server(Arc::clone(&store), 1);
    let a = srv_a.submit(wordcount::pipeline("a/in/", "a/out/", 2, 4).unwrap()).unwrap();
    a.join().unwrap();
    srv_a.shutdown().unwrap();

    // B's spills survived A's shutdown; B completes normally
    assert!(
        !store.list(&b_ns).is_empty(),
        "server A's shutdown reaped server B's live shuffle"
    );
    gate.open();
    b.join().unwrap();
    assert!(store.exists("b/out/part-r-00000"));
    assert!(store.list(SHUFFLE_NS).is_empty(), "B cleaned up after itself");
    srv_b.shutdown().unwrap();
}

#[test]
fn server_shutdown_cancels_stragglers_and_reaps() {
    let dir = TempDir::new("jobv2-shutdown").unwrap();
    let store: Arc<dyn ObjectStore> = tls(&dir);
    store.write("s/in/x", b"payload").unwrap();
    let srv = server(Arc::clone(&store), 2);
    let gate = Gate::new();
    let entered = Arc::new(AtomicUsize::new(0));
    let h = srv.submit(gated_spec("straggler", "s/in/", "s/out/", &gate, &entered)).unwrap();
    wait_for("straggler to start", || entered.load(Ordering::SeqCst) > 0);
    gate.open(); // shutdown cancels; the parked task must be released
    srv.shutdown().unwrap();
    assert!(h.is_finished());
    assert!(store.list(SHUFFLE_NS).is_empty());
}
