//! Cluster chaos suite: the full coordinator/worker protocol over the
//! deterministic loopback transport — worker kills included — inside
//! one test process. No real sockets, no sleeps, no timing assumptions:
//! every blocking edge is a condvar or a channel, and worker death is
//! injected by [`Worker::die_after_assignments`], which drops the
//! connection upon *receiving* an assignment (executing nothing), so
//! the set of re-executed tasks is exact rather than racy.
//!
//! Scenario 1: kill one of two workers mid-TeraSort → the job completes,
//! TeraValidate passes, and the dead worker's task is re-executed
//! exactly once. Scenario 2: kill the *last* worker → the job fails with
//! a diagnosable status, shuffle residue survives (the coordinator only
//! reaps on success), and [`Recover`] cleans it. Scenario 3: kill a
//! *tiered* worker (a `TwoLevelStore` over the shared striped
//! `RemotePfs`) after it completes a map task → its checkpointed spills
//! outlive its memory tier, only its in-flight task re-executes, the
//! report carries per-tier read bytes, and `recover()` reaps the staged
//! stripes an abandoned writer left behind.

use std::sync::Arc;
use std::thread;

use tlstore::cluster::{
    serve, ClusterJob, Coordinator, CoordinatorConfig, Listener, LoopbackNet, RemotePfs,
    Transport, Worker, WorkerSummary,
};
use tlstore::error::Error;
use tlstore::storage::memstore::MemStore;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, ObjectWriter as _, Recover, SHUFFLE_NS};
use tlstore::terasort::{self, SortKernel, RECORD_SIZE};
use tlstore::testing::{master_seed, TempDir};

const COORD_ADDR: &str = "coord:7000";

fn spawn_worker(
    net: &LoopbackNet,
    store: &Arc<dyn ObjectStore>,
    kernel: &Arc<SortKernel>,
    die_after: Option<u64>,
) -> thread::JoinHandle<WorkerSummary> {
    let net = net.clone();
    let store = Arc::clone(store);
    let kernel = Arc::clone(kernel);
    thread::spawn(move || {
        let mut w = Worker::new(store, kernel);
        if let Some(n) = die_after {
            w = w.die_after_assignments(n);
        }
        let conn = net.connect(COORD_ADDR).expect("worker connect");
        w.run(conn).expect("worker protocol error")
    })
}

/// Kill one of two workers mid-job: the job completes, the output
/// validates against the input checksum, and the dead worker's one
/// in-flight task is re-executed exactly once.
#[test]
fn worker_death_mid_job_reexecutes_exactly_once() {
    let seed = master_seed();
    let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new(u64::MAX, "lru").unwrap());
    let kernel = Arc::new(SortKernel::Cpu);

    // 6 input objects of 500 records → 6 map splits, 3 preferred per node.
    let records = 3_000u64;
    terasort::teragen(store.as_ref(), "in/", records, 500, seed).unwrap();
    let (in_records, in_checksum) = terasort::input_checksum(store.as_ref(), "in/").unwrap();
    assert_eq!(in_records, records);

    let net = LoopbackNet::new();
    let coord = Coordinator::new(
        net.listen(COORD_ADDR).unwrap(),
        Arc::clone(&store),
        Arc::clone(&kernel),
        CoordinatorConfig {
            expected_workers: 2,
            epoch: 0xC1,
            grace_ms: 60_000,
        },
    );

    // Whichever node the dying worker lands on, the strict two-tier
    // dispatch guarantees its first assignment is one of its own node's
    // map tasks — it dies holding exactly that one, never-executed task.
    let survivor = spawn_worker(&net, &store, &kernel, None);
    let casualty = spawn_worker(&net, &store, &kernel, Some(1));

    let report = coord
        .run(&ClusterJob {
            name: "sort".into(),
            input_prefix: "in/".into(),
            output_prefix: "out/".into(),
            reducers: 4,
            split_size: 500 * RECORD_SIZE as u64,
            sample_objects: 2,
        })
        .expect("job must survive a single worker death");
    coord.shutdown();

    let died = casualty.join().unwrap();
    assert!(died.died, "fault injector must have fired");
    assert_eq!(died.tasks_done, 0, "the casualty executed nothing");
    let lived = survivor.join().unwrap();
    assert!(!lived.died);
    assert!(lived.job_failed.is_none());

    // Exactly one task re-executed: the casualty's single assignment.
    assert_eq!(report.workers_lost, 1);
    assert_eq!(report.workers_seen, 2);
    assert_eq!(
        report.reexecuted.len(),
        1,
        "exactly the casualty's task re-executes: {:?}",
        report.reexecuted
    );
    assert_eq!(report.attempts[&report.reexecuted[0]], 2);
    assert_eq!(report.map_tasks, 6);
    assert_eq!(report.reduce_tasks, 4);
    assert_eq!(
        lived.tasks_done,
        report.map_tasks + report.reduce_tasks,
        "the survivor executed every task"
    );
    // The job id carries the epoch namespace.
    assert!(
        report.job_id.starts_with("job-e000000c1-"),
        "epoch missing from {}",
        report.job_id
    );

    // Output validates: sorted, complete, checksum-preserving.
    let v = terasort::teravalidate(store.as_ref(), "out/").unwrap();
    assert!(v.sorted, "terasort output must be sorted");
    assert_eq!(v.records, records);
    assert_eq!(v.checksum, in_checksum, "records must survive the shuffle");

    // Success path reaps the job's shuffle namespace.
    assert!(
        store.list(SHUFFLE_NS).is_empty(),
        "no shuffle residue after a successful job"
    );
}

/// Kill the *last* worker: the job fails with a diagnosable status, the
/// coordinator leaves the shuffle residue in place, and `recover()` on
/// the store reaps it.
#[test]
fn last_worker_death_fails_cleanly_and_recovery_reaps_shuffle() {
    let seed = master_seed();
    let dir = TempDir::new("cluster-chaos").unwrap();
    let pfs = Arc::new(Pfs::open(dir.path(), 2, 64 << 10).unwrap());
    let store: Arc<dyn ObjectStore> = Arc::clone(&pfs) as Arc<dyn ObjectStore>;
    let kernel = Arc::new(SortKernel::Cpu);

    // 4 map splits; the lone worker completes exactly one (its spills
    // land in .shuffle/) and dies receiving the second.
    terasort::teragen(store.as_ref(), "in/", 1_000, 250, seed).unwrap();

    let net = LoopbackNet::new();
    let coord = Coordinator::new(
        net.listen(COORD_ADDR).unwrap(),
        Arc::clone(&store),
        Arc::clone(&kernel),
        CoordinatorConfig {
            expected_workers: 1,
            epoch: 0xC2,
            grace_ms: 60_000,
        },
    );
    let worker = spawn_worker(&net, &store, &kernel, Some(2));

    let err = coord
        .run(&ClusterJob {
            name: "sort".into(),
            input_prefix: "in/".into(),
            output_prefix: "out/".into(),
            reducers: 2,
            split_size: 250 * RECORD_SIZE as u64,
            sample_objects: 0,
        })
        .expect_err("losing every worker must fail the job");
    match &err {
        Error::Job(msg) => {
            assert!(
                msg.contains("all workers lost"),
                "status must name the cause: {msg}"
            );
            assert!(
                msg.contains("stranded"),
                "status must count the stranded tasks: {msg}"
            );
        }
        other => panic!("expected Error::Job, got {other}"),
    }
    coord.shutdown();

    let summary = worker.join().unwrap();
    assert!(summary.died);
    assert_eq!(summary.tasks_done, 1, "one map completed before the kill");

    // Failure leaves the evidence in place: the completed map's spills.
    assert!(
        !store.list(SHUFFLE_NS).is_empty(),
        "failed jobs keep their shuffle residue for recovery to reap"
    );

    // Recovery — not the coordinator — owns post-crash cleanup.
    let report = pfs.recover().unwrap();
    assert!(report.shuffle_reaped > 0, "{report:?}");
    assert!(
        store.list(SHUFFLE_NS).is_empty(),
        "recover() must reap the shuffle namespace"
    );
    // The input survives recovery untouched.
    let (in_records, _) = terasort::input_checksum(store.as_ref(), "in/").unwrap();
    assert_eq!(in_records, 1_000);
}

/// Kill one of two *tiered* workers — each a [`TwoLevelStore`] whose
/// PFS tier is the shared striped [`RemotePfs`] — after it completes
/// one map task. The worker's memory tier dies with it; its MemOnly
/// spills were checkpointed to the remote tier before `TaskDone`, so
/// only the in-flight assignment re-executes and the reducers consume
/// the dead worker's spills without a re-run. The `ClusterReport`
/// carries nonzero mem-tier *and* remote-tier read bytes, and a final
/// `recover()` reaps the staged stripes an abandoned writer stranded.
#[test]
fn tiered_worker_death_reexecutes_once_and_recovery_reaps_staged() {
    const STRIPE: u64 = 4 << 10;
    let seed = master_seed();
    let net = LoopbackNet::new();

    // Three loopback stripe servers — the cluster's shared PFS tier.
    let mut addrs = Vec::new();
    let mut listeners: Vec<Arc<dyn Listener>> = Vec::new();
    let mut servers = Vec::new();
    for i in 0..3 {
        let addr = format!("pfs{i}:7100");
        let listener: Arc<dyn Listener> = Arc::from(net.listen(&addr).unwrap());
        let backing: Arc<dyn ObjectStore> = Arc::new(MemStore::new(u64::MAX, "lru").unwrap());
        let l2 = Arc::clone(&listener);
        servers.push(thread::spawn(move || {
            serve(l2, backing).expect("stripe server");
        }));
        addrs.push(addr);
        listeners.push(listener);
    }

    let kernel = Arc::new(SortKernel::Cpu);
    let store: Arc<dyn ObjectStore> =
        Arc::new(RemotePfs::connect(&net, &addrs, STRIPE).unwrap());

    // 6 input objects of 500 records → 6 map splits, 3 preferred per node.
    let records = 3_000u64;
    terasort::teragen(store.as_ref(), "in/", records, 500, seed).unwrap();
    let (in_records, in_checksum) = terasort::input_checksum(store.as_ref(), "in/").unwrap();
    assert_eq!(in_records, records);

    let coord = Coordinator::new(
        net.listen(COORD_ADDR).unwrap(),
        Arc::clone(&store),
        Arc::clone(&kernel),
        CoordinatorConfig {
            expected_workers: 2,
            epoch: 0xC3,
            grace_ms: 60_000,
        },
    );

    let spawn_tiered = |die_after: Option<u64>| {
        let net = net.clone();
        let addrs = addrs.clone();
        let kernel = Arc::clone(&kernel);
        thread::spawn(move || {
            let remote = RemotePfs::connect(&net, &addrs, STRIPE).unwrap();
            let cfg = TlsConfig::builder("chaos-worker-tier")
                .mem_capacity(8 << 20)
                .block_size(4 << 10)
                .build()
                .unwrap();
            let tls = Arc::new(TwoLevelStore::with_tier(cfg, remote).unwrap());
            let mut w = Worker::tiered(tls, kernel);
            if let Some(n) = die_after {
                w = w.die_after_assignments(n);
            }
            let conn = net.connect(COORD_ADDR).expect("worker connect");
            w.run(conn).expect("worker protocol error")
        })
    };

    let survivor = spawn_tiered(None);
    // Dies receiving its *second* assignment: the first map completed
    // and its spills checkpointed before the kill.
    let casualty = spawn_tiered(Some(2));

    let report = coord
        .run(&ClusterJob {
            name: "sort".into(),
            input_prefix: "in/".into(),
            output_prefix: "out/".into(),
            reducers: 4,
            split_size: 500 * RECORD_SIZE as u64,
            sample_objects: 2,
        })
        .expect("job must survive a single tiered-worker death");
    coord.shutdown();

    let died = casualty.join().unwrap();
    assert!(died.died, "fault injector must have fired");
    assert_eq!(died.tasks_done, 1, "one map completed before the kill");
    let lived = survivor.join().unwrap();
    assert!(!lived.died);

    // Exactly-once: only the casualty's in-flight task re-executes. Its
    // *completed* map is not re-run — the checkpointed spills survived
    // the loss of the worker's memory tier.
    assert_eq!(report.workers_lost, 1);
    assert_eq!(report.workers_seen, 2);
    assert_eq!(
        report.reexecuted.len(),
        1,
        "exactly the casualty's in-flight task re-executes: {:?}",
        report.reexecuted
    );
    assert_eq!(report.attempts[&report.reexecuted[0]], 2);
    assert_eq!(
        lived.tasks_done,
        report.map_tasks + report.reduce_tasks - 1,
        "the survivor executed everything but the casualty's completed map"
    );

    // The per-tier accounting reached the coordinator: spill
    // checkpoints and shuffle-local reads hit the memory tier, input
    // faults cross the wire to the remote tier.
    assert!(report.mem_read_bytes() > 0, "mem-tier hit bytes must be reported");
    assert!(report.remote_read_bytes() > 0, "remote-tier bytes must be reported");
    let f = report
        .observed_read_residency()
        .expect("a tiered job must have an observed residency");
    assert!(f > 0.0 && f < 1.0, "residency {f} must be a genuine mix");

    // Output validates: sorted, complete, checksum-preserving.
    let v = terasort::teravalidate(store.as_ref(), "out/").unwrap();
    assert!(v.sorted, "terasort output must be sorted");
    assert_eq!(v.records, records);
    assert_eq!(v.checksum, in_checksum, "records must survive the shuffle");
    assert!(
        store.list(SHUFFLE_NS).is_empty(),
        "no shuffle residue after a successful job"
    );

    // A client killed mid-write strands staged stripe temps on the
    // servers; `recover()` on a fresh tiered store (the worker's own
    // shape) reaps them.
    let crash = RemotePfs::connect(&net, &addrs, STRIPE).unwrap();
    let mut w = crash.create("crash/obj").unwrap();
    w.append(&vec![7u8; (STRIPE * 2 + 100) as usize]).unwrap();
    std::mem::forget(w); // the "kill": no Drop cleanup runs

    let cfg = TlsConfig::builder("chaos-recover-tier")
        .mem_capacity(1 << 20)
        .block_size(4 << 10)
        .build()
        .unwrap();
    let fresh =
        TwoLevelStore::with_tier(cfg, RemotePfs::connect(&net, &addrs, STRIPE).unwrap()).unwrap();
    let rep = fresh.recover().unwrap();
    assert!(
        rep.temps_removed >= 2,
        "the abandoned writer's staged stripes must be reaped: {rep:?}"
    );
    assert!(!fresh.exists("crash/obj"), "a never-committed object stays invisible");

    // Drop every client conn, then close the listeners so the server
    // threads exit cleanly.
    drop(coord);
    drop(fresh);
    drop(crash);
    drop(store);
    for l in &listeners {
        l.close();
    }
    for t in servers {
        t.join().unwrap();
    }
}
