//! Crash-recovery integration suite: the paper's "Tachyon restart over
//! OrangeFS" scenario (memory tier dies, PFS survives, `recover()` makes
//! the union trustworthy again), plus randomized workload × seeded
//! `FaultPlan` runs.
//!
//! Seeds: three are fixed; CI adds one derived from `$GITHUB_RUN_ID` via
//! the `TLSTORE_CRASH_SEED` env var. Every run prints its seed so a CI
//! failure reproduces locally with
//! `TLSTORE_CRASH_SEED=<seed> cargo test --test crash_storage`.

#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::path::Path;

use tlstore::storage::fault::{FaultPlan, FaultStore, OpKind};
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, ReadMode, WriteMode};
use tlstore::testing::crash::{
    assert_no_residue, payload, run_to_crash, verify_after_recovery, Workload,
};
use tlstore::testing::TempDir;
use tlstore::util::rng::Pcg32;

fn tls(root: &Path) -> TwoLevelStore {
    let cfg = TlsConfig::builder(root)
        .mem_capacity(64 << 10)
        .block_size(1024)
        .pfs_servers(3)
        .stripe_size(300) // non-power-of-two: stripes straddle blocks
        .pfs_buffer(512)
        .build()
        .unwrap();
    TwoLevelStore::open(cfg).unwrap()
}

/// Three fixed seeds plus an environment-provided one (if any):
/// `TLSTORE_CRASH_SEED` (the crash-suite-specific override CI drives)
/// takes precedence over the repo-wide `TLSTORE_SEED` master.
fn seeds() -> Vec<u64> {
    let mut v = vec![0xC0_FFEE, 42, 20_150_831];
    if let Ok(s) = std::env::var("TLSTORE_CRASH_SEED") {
        match s.parse() {
            Ok(n) => v.push(n),
            Err(_) => panic!("TLSTORE_CRASH_SEED must be a u64, got `{s}`"),
        }
    } else if std::env::var("TLSTORE_SEED").is_ok() {
        v.push(tlstore::testing::master_seed());
    }
    v
}

#[test]
fn tachyon_restart_over_orangefs_scenario() {
    // the paper's restart story, end to end: write-through and
    // checkpointed mode-(a) data survive the memory tier's death;
    // uncheckpointed mode-(a) data is volatile and must NOT resurrect
    let dir = TempDir::new("crash-restart").unwrap();
    let durable = payload("jobs/out", 1, 5000);
    let ckpt = payload("jobs/ckpt", 1, 3000);
    let volatile = payload("jobs/tmp", 1, 2000);
    {
        let s = tls(dir.path());
        s.write("jobs/out", &durable, WriteMode::WriteThrough).unwrap();
        s.write("jobs/ckpt", &ckpt, WriteMode::MemOnly).unwrap();
        s.checkpoint("jobs/ckpt").unwrap();
        s.write("jobs/tmp", &volatile, WriteMode::MemOnly).unwrap();
    } // restart: the memory tier evaporates
    let s = tls(dir.path());
    let report = s.recover().unwrap();
    assert_eq!(s.read("jobs/out", ReadMode::TwoLevel).unwrap(), durable);
    assert_eq!(s.read("jobs/ckpt", ReadMode::TwoLevel).unwrap(), ckpt);
    assert!(
        matches!(s.read("jobs/tmp", ReadMode::TwoLevel), Err(tlstore::Error::NotFound(_))),
        "uncheckpointed mode-(a) data is volatile by contract"
    );
    let _ = report; // may or may not have spill debris depending on eviction
    assert_no_residue(dir.path(), "restart scenario");
}

#[test]
fn recovery_is_idempotent() {
    let dir = TempDir::new("crash-idem").unwrap();
    let w = Workload::default().put("k", 1, 2000, 300).put("k", 2, 1500, 256);
    let outcome = {
        let faulty = FaultStore::new(tls(dir.path()), FaultPlan::crash_at(OpKind::Append, 9));
        run_to_crash(&faulty, &w)
    };
    assert!(outcome.crashed);
    let s = tls(dir.path());
    s.recover().unwrap();
    // a second pass finds nothing left to do
    assert!(s.recover().unwrap().is_clean(), "recover must be idempotent");
    verify_after_recovery(&s, &outcome, true, "idempotence");
    assert_no_residue(dir.path(), "idempotence");
}

#[test]
fn crash_during_overwrite_preserves_committed_version_exactly() {
    // pin the strictest case: v1 fully committed, v2 crashes at its
    // commit boundary — after recovery v1 must be byte-identical, v2
    // must be nowhere (not in the PFS, not in the cache)
    let dir = TempDir::new("crash-ow").unwrap();
    let w = Workload::default().put("k", 1, 4000, 512).put("k", 2, 4000, 512);
    let outcome = {
        // ceil(4000/512) = 8 appends per put; commit #1 is v2's
        let faulty = FaultStore::new(tls(dir.path()), FaultPlan::crash_at(OpKind::Commit, 1));
        run_to_crash(&faulty, &w)
    };
    assert!(outcome.crashed);
    let s = tls(dir.path());
    s.recover().unwrap();
    assert_eq!(
        s.read("k", ReadMode::TwoLevel).unwrap(),
        payload("k", 1, 4000),
        "old version must survive an overwrite crash byte-for-byte"
    );
    assert_eq!(s.read("k", ReadMode::Bypass).unwrap(), payload("k", 1, 4000));
    assert_no_residue(dir.path(), "overwrite crash");
}

#[test]
fn randomized_workloads_with_seeded_faults_recover_consistently() {
    for seed in seeds() {
        eprintln!("crash-recovery property: TLSTORE_CRASH_SEED={seed}");
        for round in 0..8u64 {
            let case_seed = seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let ctx = format!("seed {seed} round {round} (case {case_seed:#x})");
            // random workload over a small key set: puts of random
            // size/chunk, occasional deletes, repeated overwrites
            let mut rng = Pcg32::new(case_seed, 0xC4A5);
            let keys = ["w/a", "w/b", "w/c", "w/d"];
            let mut versions = [0u64; 4];
            let mut w = Workload::default();
            for _ in 0..(3 + rng.gen_range(6)) {
                let ki = rng.gen_range(4) as usize;
                if rng.gen_range(5) == 0 {
                    w = w.delete(keys[ki]);
                } else {
                    versions[ki] += 1;
                    let size = rng.gen_range(3000) as usize;
                    let chunk = 64 + rng.gen_range(512) as usize;
                    w = w.put(keys[ki], versions[ki], size, chunk);
                }
            }
            let dir = TempDir::new(&format!("crash-rand-{seed}-{round}")).unwrap();
            let outcome = {
                let faulty = FaultStore::new(tls(dir.path()), FaultPlan::seeded(case_seed));
                run_to_crash(&faulty, &w)
            };
            // reboot + recover + invariant
            let s = tls(dir.path());
            s.recover().unwrap_or_else(|e| panic!("{ctx}: recover failed: {e}"));
            verify_after_recovery(&s, &outcome, true, &ctx);
            assert_no_residue(dir.path(), &ctx);
            // the capacity accountant invariant holds after recovery and
            // the verification reads (which re-warm the cache)
            assert!(
                s.mem().used() <= s.mem().capacity(),
                "{ctx}: used {} > capacity {}",
                s.mem().used(),
                s.mem().capacity()
            );
        }
    }
}

#[test]
fn midcommit_rename_crash_leaves_recoverable_tree() {
    // hand-crafted worst case for the PFS: a fresh-key commit died
    // *between* datafile renames and the meta write — published-looking
    // datafiles with no owning metadata, plus staging of a second writer
    let dir = TempDir::new("crash-midcommit").unwrap();
    {
        let s = tls(dir.path());
        s.write("live", &payload("live", 1, 2500), WriteMode::WriteThrough)
            .unwrap();
        let pfs_root = dir.path().join("pfs");
        for server in 0..2 {
            std::fs::write(
                pfs_root.join(format!("server{server}")).join("ghost.df"),
                b"renamed-before-meta",
            )
            .unwrap();
        }
        std::fs::write(pfs_root.join("server2").join("part.df.tmp-17"), b"staging").unwrap();
        std::fs::write(pfs_root.join("meta").join("torn.meta.tmp"), b"size = 1\n").unwrap();
    }
    let s = tls(dir.path());
    assert!(!s.exists("ghost"), "meta never landed → never visible");
    let report = s.recover().unwrap();
    assert_eq!(report.orphans_removed, 2, "{report}");
    assert_eq!(report.temps_removed, 2, "{report}");
    assert!(report.quarantined.is_empty(), "{report}");
    assert_eq!(
        s.read("live", ReadMode::TwoLevel).unwrap(),
        payload("live", 1, 2500)
    );
    assert_no_residue(dir.path(), "midcommit");
}

/// Crash a job at a `.shuffle/` boundary, reboot, recover: the shuffle
/// namespace must come back empty (spills are recomputable — deleted,
/// never quarantined), no writer temps may survive anywhere, and the
/// job's input must still be intact. Covers both shapes of shuffle
/// write: a *map task* streaming a spill run, and a *round-1 reducer*
/// streaming intermediate output into `.shuffle/<job>/inter-1/`.
#[test]
fn crash_at_shuffle_boundaries_leaves_no_residue_after_recover() {
    use tlstore::mapreduce::{JobServer, JobServerConfig};
    use tlstore::storage::{ObjectStore, SHUFFLE_NS};
    use tlstore::workloads::wordcount;

    // one crash per shuffle-write shape: mapper spill append, mapper
    // spill commit, reducer intermediate-output append
    let plans = [
        ("map spill append", "op=append,kind=crash,key=/s0/,after=1"),
        ("map spill commit", "op=commit,kind=crash,key=/s0/,after=0"),
        ("reducer inter append", "op=append,kind=crash,key=/inter-1/,after=0"),
    ];
    for (i, (tag, plan)) in plans.into_iter().enumerate() {
        let dir = TempDir::new(&format!("crash-shuffle-{i}")).unwrap();
        {
            let faulty = std::sync::Arc::new(FaultStore::new(
                tls(dir.path()),
                FaultPlan::parse(plan).unwrap(),
            ));
            // generation is untouched: the triggers key-filter on the
            // shuffle namespace
            wordcount::generate_text(faulty.as_ref(), "wc/in/", 3, 400, 17).unwrap();
            let server = JobServer::new(
                std::sync::Arc::clone(&faulty) as std::sync::Arc<dyn ObjectStore>,
                JobServerConfig {
                    workers: 2,
                    max_concurrent_jobs: 1,
                    shuffle_spill_threshold: 0,
                    shuffle_chunk: 1 << 10,
                    ..JobServerConfig::default()
                },
            );
            let handle = server
                .submit(wordcount::pipeline("wc/in/", "wc/out/", 2, 5).unwrap())
                .unwrap();
            let err = handle.join().unwrap_err();
            assert!(
                matches!(err, tlstore::Error::Injected(_)),
                "{tag}: expected the armed crash, got {err}"
            );
            assert!(faulty.crashed(), "{tag}: wrapper must report the crash");
            // the dead store refuses cleanup: residue survives on disk,
            // exactly like kill -9 mid-job
            let _ = server.shutdown();
        }
        // reboot over the surviving tree
        let s = tls(dir.path());
        let report = s.recover().unwrap_or_else(|e| panic!("{tag}: recover failed: {e}"));
        assert!(
            ObjectStore::list(&s, SHUFFLE_NS).is_empty(),
            "{tag}: shuffle residue after recover: {report}"
        );
        assert!(
            report.quarantined.iter().all(|k| !k.contains(".shuffle/")),
            "{tag}: shuffle data must be dropped, not quarantined: {report}"
        );
        assert_no_residue(dir.path(), tag);
        // the job's input is untouched; its output never published
        assert_eq!(ObjectStore::list(&s, "wc/in/").len(), 3, "{tag}");
        wordcount::count_words(&s, "wc/in/").unwrap_or_else(|e| panic!("{tag}: input torn: {e}"));
        assert!(ObjectStore::list(&s, "wc/out/").is_empty(), "{tag}: partial output");
        // recovery is idempotent here too
        assert!(s.recover().unwrap().is_clean(), "{tag}: second pass dirty");
    }
}

/// The shuffle crash story again, but with the hot-path overlap knobs
/// *on*: coalesced appends batching the spill stream's small writes,
/// and `overlap_depth = 2` arming the eager-merge primer (plus split
/// prefetch). New crash boundaries this opens up:
///
/// - a spill append dies while the writer's carry holds batched,
///   unflushed bytes;
/// - a spill commit dies before the carry-flush runs — the tail of the
///   run is lost whole;
/// - a spill *read* dies mid-eager-merge (the primer or a reducer
///   cursor is walking the run when the store goes down).
///
/// In every case the contract is unchanged: the job fails with the
/// injected error (the primer must swallow its own read error and shut
/// down rather than hang), and after reboot + `recover()` the shuffle
/// namespace is empty, no writer temps survive, and the input is
/// intact.
#[test]
fn crash_with_overlap_knobs_on_leaves_no_residue_after_recover() {
    use tlstore::mapreduce::{JobServer, JobServerConfig};
    use tlstore::storage::{ObjectStore, SHUFFLE_NS};
    use tlstore::workloads::wordcount;

    fn tls_overlapped(root: &Path) -> TwoLevelStore {
        let cfg = TlsConfig::builder(root)
            .mem_capacity(64 << 10)
            .block_size(1024)
            .pfs_servers(3)
            .stripe_size(300)
            .pfs_buffer(512)
            .append_coalesce(2048) // batches the spill stream's appends
            .build()
            .unwrap();
        TwoLevelStore::open(cfg).unwrap()
    }

    let plans = [
        ("coalesced spill append", "op=append,kind=crash,key=/s0/,after=1"),
        ("coalesced spill commit", "op=commit,kind=crash,key=/s0/,after=0"),
        ("eager-merge spill read", "op=read-at,kind=crash,key=/s0/,after=0"),
    ];
    for (i, (tag, plan)) in plans.into_iter().enumerate() {
        let dir = TempDir::new(&format!("crash-overlap-{i}")).unwrap();
        {
            let faulty = std::sync::Arc::new(FaultStore::new(
                tls_overlapped(dir.path()),
                FaultPlan::parse(plan).unwrap(),
            ));
            wordcount::generate_text(faulty.as_ref(), "wc/in/", 3, 400, 23).unwrap();
            let server = JobServer::new(
                std::sync::Arc::clone(&faulty) as std::sync::Arc<dyn ObjectStore>,
                JobServerConfig {
                    workers: 2,
                    max_concurrent_jobs: 1,
                    shuffle_spill_threshold: 0,
                    shuffle_chunk: 1 << 10,
                    overlap_depth: 2,
                    ..JobServerConfig::default()
                },
            );
            let handle = server
                .submit(wordcount::pipeline("wc/in/", "wc/out/", 2, 5).unwrap())
                .unwrap();
            let err = handle.join().unwrap_err();
            assert!(
                matches!(err, tlstore::Error::Injected(_)),
                "{tag}: expected the armed crash, got {err}"
            );
            assert!(faulty.crashed(), "{tag}: wrapper must report the crash");
            let _ = server.shutdown();
        }
        let s = tls(dir.path());
        let report = s.recover().unwrap_or_else(|e| panic!("{tag}: recover failed: {e}"));
        assert!(
            ObjectStore::list(&s, SHUFFLE_NS).is_empty(),
            "{tag}: shuffle residue after recover: {report}"
        );
        assert_no_residue(dir.path(), tag);
        assert_eq!(ObjectStore::list(&s, "wc/in/").len(), 3, "{tag}");
        assert!(ObjectStore::list(&s, "wc/out/").is_empty(), "{tag}: partial output");
        assert!(s.recover().unwrap().is_clean(), "{tag}: second pass dirty");
    }
}

#[test]
fn fault_plan_cli_grammar_smoke() {
    // the spec strings documented for --fault-plan parse to working plans
    let dir = TempDir::new("crash-cli-plan").unwrap();
    let plan = FaultPlan::parse("op=commit,kind=crash,after=0").unwrap();
    let faulty = FaultStore::new(tls(dir.path()), plan);
    let w = Workload::default().put("x", 1, 1000, 256);
    let outcome = run_to_crash(&faulty, &w);
    assert!(outcome.crashed);
    assert!(faulty.crashed());
}
