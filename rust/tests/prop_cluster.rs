//! Property tests for the cluster wire protocol (`cluster::wire`):
//! seeded random frame streams round-trip byte-exactly, and every
//! corruption mode — truncation, bit flips, oversized length prefixes,
//! unknown tags — surfaces as a typed [`Error::Wire`], never a panic,
//! hang, or silent misparse.
//!
//! Seeds come from [`tlstore::testing::master_seed`] (`TLSTORE_SEED`
//! env override); failures print a reproduction seed.

use tlstore::cluster::wire::{
    frame_bytes, read_message, write_message, Message, Role, TaskKind, TaskSpec, TierIo,
    MAX_FRAME, WIRE_VERSION,
};
use tlstore::error::{Error, WireKind};
use tlstore::storage::block::Crc32;
use tlstore::testing::{proprun, PropConfig};
use tlstore::util::rng::Pcg32;

// ------------------------------------------------------------ generators

fn gen_string(rng: &mut Pcg32, max_len: usize) -> String {
    let len = rng.gen_range(max_len.max(1) as u32) as usize;
    (0..len)
        .map(|_| {
            let c = rng.gen_range(38);
            match c {
                0..=25 => (b'a' + c as u8) as char,
                26..=35 => (b'0' + (c - 26) as u8) as char,
                36 => '/',
                _ => '-',
            }
        })
        .collect()
}

fn gen_task_spec(rng: &mut Pcg32, size: usize) -> TaskSpec {
    let kind = if rng.gen_range(2) == 0 {
        TaskKind::Map {
            object: gen_string(rng, size.max(2)),
            offset: rng.next_u64() % (1 << 40),
            len: rng.next_u64() % (1 << 30),
            task_index: rng.next_u32() % 10_000,
            partitions: 1 + rng.gen_range(256),
            bucket_map: (0..256).map(|_| rng.gen_range(256)).collect(),
            shuffle_prefix: gen_string(rng, size.max(2)),
        }
    } else {
        TaskKind::Reduce {
            partition: rng.gen_range(256),
            spill_keys: (0..rng.gen_range(1 + size.min(8) as u32))
                .map(|_| gen_string(rng, size.max(2)))
                .collect(),
            out_key: gen_string(rng, size.max(2)),
        }
    };
    TaskSpec {
        task_id: rng.next_u64(),
        job_id: gen_string(rng, size.max(2)),
        attempt: rng.gen_range(4),
        preferred_node: if rng.gen_range(2) == 0 {
            None
        } else {
            Some(rng.gen_range(64))
        },
        kind,
    }
}

fn gen_message(rng: &mut Pcg32, size: usize) -> Message {
    let data_len = rng.gen_range(1 + size.min(512) as u32) as usize;
    let mut data = vec![0u8; data_len];
    rng.fill_bytes(&mut data);
    match rng.gen_range(22) {
        0 => Message::Hello {
            version: WIRE_VERSION,
            role: if rng.gen_range(2) == 0 {
                Role::Worker
            } else {
                Role::PfsClient
            },
            epoch: rng.next_u64(),
        },
        1 => Message::HelloAck {
            version: WIRE_VERSION,
            epoch: rng.next_u64(),
            worker_id: rng.next_u64(),
        },
        2 => Message::Put {
            key: gen_string(rng, size.max(2)),
            data,
        },
        3 => Message::GetRange {
            key: gen_string(rng, size.max(2)),
            offset: rng.next_u64(),
            len: rng.next_u32(),
        },
        4 => Message::Stat {
            key: gen_string(rng, size.max(2)),
        },
        5 => Message::Delete {
            key: gen_string(rng, size.max(2)),
        },
        6 => Message::List {
            prefix: gen_string(rng, size.max(2)),
        },
        7 => Message::Get {
            key: gen_string(rng, size.max(2)),
        },
        8 => Message::OkUnit,
        9 => Message::OkBytes { data },
        10 => Message::OkMeta {
            size: rng.next_u64(),
        },
        11 => Message::OkKeys {
            keys: (0..rng.gen_range(1 + size.min(8) as u32))
                .map(|_| gen_string(rng, size.max(2)))
                .collect(),
        },
        12 => Message::ErrReply {
            code: (rng.next_u32() % 256) as u8,
            msg: gen_string(rng, size.max(2)),
        },
        13 => Message::Heartbeat {
            worker_id: rng.next_u64(),
        },
        14 => Message::HeartbeatAck,
        15 => Message::ReqTask {
            worker_id: rng.next_u64(),
        },
        16 => Message::TaskAssign(gen_task_spec(rng, size)),
        17 => Message::NoTask {
            failed: rng.gen_range(2) == 0,
            msg: gen_string(rng, size.max(2)),
        },
        18 => Message::TaskDone {
            worker_id: rng.next_u64(),
            task_id: rng.next_u64(),
            spills: (0..rng.gen_range(1 + size.min(6) as u32))
                .map(|_| (rng.gen_range(256), gen_string(rng, size.max(2))))
                .collect(),
            bytes_read: rng.next_u64(),
            bytes_written: rng.next_u64(),
            micros: rng.next_u64(),
            tier_io: TierIo {
                mem_read_bytes: rng.next_u64(),
                mem_read_micros: rng.next_u64(),
                remote_read_bytes: rng.next_u64(),
                remote_read_micros: rng.next_u64(),
                mem_write_bytes: rng.next_u64(),
                mem_write_micros: rng.next_u64(),
                remote_write_bytes: rng.next_u64(),
                remote_write_micros: rng.next_u64(),
                wall_micros: rng.next_u64(),
            },
        },
        19 => Message::TaskFail {
            worker_id: rng.next_u64(),
            task_id: rng.next_u64(),
            error: gen_string(rng, size.max(2)),
        },
        20 => Message::Rename {
            from: gen_string(rng, size.max(2)),
            to: gen_string(rng, size.max(2)),
        },
        _ => Message::Hello {
            version: rng.next_u32(),
            role: Role::Worker,
            epoch: rng.next_u64(),
        },
    }
}

fn gen_stream(rng: &mut Pcg32, size: usize) -> Vec<Message> {
    let n = 1 + rng.gen_range(1 + size.min(12) as u32) as usize;
    (0..n).map(|_| gen_message(rng, size)).collect()
}

fn assert_wire_err(result: Result<Option<Message>, Error>, what: &str) -> Result<(), String> {
    match result {
        Err(Error::Wire { .. }) => Ok(()),
        Ok(m) => Err(format!("{what}: decoded {m:?} instead of failing")),
        Err(e) => Err(format!("{what}: non-wire error {e}")),
    }
}

// ------------------------------------------------------------ properties

#[test]
fn prop_valid_streams_round_trip_byte_exact() {
    proprun(
        "valid frame streams round-trip",
        PropConfig::default(),
        gen_stream,
        |msgs| {
            // Encode the whole stream into one buffer...
            let mut wire = Vec::new();
            for m in msgs {
                write_message(&mut wire, m).map_err(|e| format!("write: {e}"))?;
                // frame_bytes must agree with write_message byte-for-byte
                let lone = frame_bytes(m);
                let tail = &wire[wire.len() - lone.len()..];
                if tail != lone.as_slice() {
                    return Err("frame_bytes and write_message disagree".into());
                }
            }
            // ...and read every message back, byte-exact.
            let mut r = std::io::Cursor::new(&wire);
            for (i, want) in msgs.iter().enumerate() {
                match read_message(&mut r).map_err(|e| format!("read msg {i}: {e}"))? {
                    Some(got) if got == *want => {}
                    Some(got) => return Err(format!("msg {i}: {got:?} != {want:?}")),
                    None => return Err(format!("msg {i}: premature clean EOF")),
                }
            }
            match read_message(&mut r) {
                Ok(None) => Ok(()),
                other => Err(format!("expected clean EOF, got {other:?}")),
            }
        },
    );
}

#[test]
fn prop_truncation_is_typed_never_a_panic() {
    proprun(
        "truncated frames surface WireKind::Truncated",
        PropConfig::default(),
        |rng, size| {
            let msg = gen_message(rng, size);
            let frame = frame_bytes(&msg);
            let cut = rng.gen_range(frame.len() as u32) as usize;
            (frame, cut)
        },
        |(frame, cut)| {
            let mut r = std::io::Cursor::new(&frame[..*cut]);
            match read_message(&mut r) {
                // a cut at byte 0 is a clean close, not corruption
                Ok(None) if *cut == 0 => Ok(()),
                Ok(other) => Err(format!("cut at {cut}: decoded {other:?}")),
                Err(Error::Wire { kind, .. })
                    if matches!(kind, WireKind::Truncated | WireKind::Crc) =>
                {
                    // Crc is reachable only when the mangled length still
                    // lands on readable bytes; both are typed corruption.
                    Ok(())
                }
                Err(e) => Err(format!("cut at {cut}: unexpected error {e}")),
            }
        },
    );
}

#[test]
fn prop_bit_flips_never_misparse() {
    proprun(
        "single bit flips surface a typed wire error",
        PropConfig::default(),
        |rng, size| {
            let msg = gen_message(rng, size);
            let mut frame = frame_bytes(&msg);
            let byte = rng.gen_range(frame.len() as u32) as usize;
            let bit = rng.gen_range(8) as u8;
            frame[byte] ^= 1 << bit;
            (frame, byte)
        },
        |(frame, byte)| {
            let mut r = std::io::Cursor::new(frame.as_slice());
            assert_wire_err(read_message(&mut r), &format!("flip in byte {byte}"))
        },
    );
}

#[test]
fn prop_oversized_length_rejected_before_allocation() {
    proprun(
        "oversized length prefixes surface WireKind::Oversized",
        PropConfig::default(),
        |rng, _size| {
            // a length strictly beyond MAX_FRAME, anywhere in u32 range
            let overflow = u32::MAX - MAX_FRAME;
            MAX_FRAME + 1 + rng.gen_range(overflow)
        },
        |len| {
            let mut frame = Vec::new();
            frame.extend_from_slice(&len.to_le_bytes());
            frame.push(0x30); // plausible tag
            frame.extend_from_slice(&[0u8; 16]); // far less than claimed
            let mut r = std::io::Cursor::new(frame.as_slice());
            match read_message(&mut r) {
                Err(Error::Wire {
                    kind: WireKind::Oversized,
                    ..
                }) => Ok(()),
                other => Err(format!("len {len}: got {other:?}")),
            }
        },
    );
}

#[test]
fn prop_unknown_tags_with_valid_crc_are_typed() {
    proprun(
        "unknown tags surface WireKind::UnknownTag",
        PropConfig::default(),
        |rng, size| {
            // Tags the protocol defines live in 0x01..=0x36; pick from
            // the unassigned space above.
            let tag = 0x40 + (rng.gen_range(0xC0)) as u8;
            let len = rng.gen_range(1 + size.min(64) as u32) as usize;
            let mut body = vec![0u8; len];
            rng.fill_bytes(&mut body);
            (tag, body)
        },
        |(tag, body)| {
            let mut crc = Crc32::new();
            crc.update(&[*tag]);
            crc.update(body);
            let mut frame = Vec::new();
            frame.extend_from_slice(&(body.len() as u32).to_le_bytes());
            frame.push(*tag);
            frame.extend_from_slice(body);
            frame.extend_from_slice(&crc.finish().to_le_bytes());
            let mut r = std::io::Cursor::new(frame.as_slice());
            match read_message(&mut r) {
                Err(Error::Wire {
                    kind: WireKind::UnknownTag,
                    ..
                }) => Ok(()),
                other => Err(format!("tag {tag:#04x}: got {other:?}")),
            }
        },
    );
}

#[test]
fn prop_trailing_garbage_inside_body_is_malformed() {
    proprun(
        "valid frames with padded bodies surface WireKind::Malformed",
        PropConfig::default(),
        |rng, size| {
            let msg = gen_message(rng, size);
            let pad = 1 + rng.gen_range(16) as usize;
            (msg, pad)
        },
        |(msg, pad)| {
            // Re-frame with `pad` extra body bytes and a *correct* CRC:
            // the frame layer accepts it, the decoder must reject it.
            let frame = frame_bytes(msg);
            let body_len =
                u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]]) as usize;
            let tag = frame[4];
            let mut body = frame[5..5 + body_len].to_vec();
            body.extend(std::iter::repeat(0xAB).take(*pad));
            let mut crc = Crc32::new();
            crc.update(&[tag]);
            crc.update(&body);
            let mut padded = Vec::new();
            padded.extend_from_slice(&(body.len() as u32).to_le_bytes());
            padded.push(tag);
            padded.extend_from_slice(&body);
            padded.extend_from_slice(&crc.finish().to_le_bytes());
            let mut r = std::io::Cursor::new(padded.as_slice());
            match read_message(&mut r) {
                Err(Error::Wire {
                    kind: WireKind::Malformed,
                    ..
                }) => Ok(()),
                other => Err(format!("padded {tag:#04x}: got {other:?}")),
            }
        },
    );
}
