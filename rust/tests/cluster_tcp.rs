//! Real-TCP cluster smoke test: coordinator + two workers + two PFS
//! stripe servers as separate OS processes on 127.0.0.1 ephemeral
//! ports, exercising the same scenario the loopback chaos suite proves
//! deterministically — one worker killed mid-TeraSort via
//! `--die-after-tasks`, the job completing through re-execution. The
//! surviving worker runs tiered (`--mem-capacity 16M`), so the smoke
//! also proves the two-level read path reports mem-tier hits over TCP.
//!
//! Per-process stdout/stderr land under `target/cluster-logs/` so CI
//! can upload them as artifacts when the test fails.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use tlstore::testing::TempDir;

const BIN: &str = env!("CARGO_BIN_EXE_tlstore");
const DEADLINE: Duration = Duration::from_secs(120);

fn log_dir() -> PathBuf {
    // The crate lives in a workspace, so `target/` sits next to the
    // workspace root, one level above CARGO_MANIFEST_DIR.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let dir = manifest
        .parent()
        .unwrap_or(&manifest)
        .join("target")
        .join("cluster-logs");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

struct Role {
    name: &'static str,
    child: Child,
    stdout: mpsc::Receiver<String>,
}

impl Role {
    /// Spawn a `tlstore cluster` role with piped output; stdout lines
    /// stream through a channel (and into the log file) so the test can
    /// wait for the "listening on" banner without polling.
    fn spawn(name: &'static str, args: &[String]) -> Role {
        let mut child = Command::new(BIN)
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .unwrap_or_else(|e| panic!("spawn {name}: {e}"));
        let (tx, rx) = mpsc::channel();
        let out = child.stdout.take().unwrap();
        let log = log_dir().join(format!("{name}.log"));
        std::thread::spawn(move || {
            let mut file = std::fs::File::create(&log).unwrap();
            for line in BufReader::new(out).lines().map_while(Result::ok) {
                writeln!(file, "{line}").ok();
                let _ = tx.send(line);
            }
        });
        let err = child.stderr.take().unwrap();
        let errlog = log_dir().join(format!("{name}.stderr.log"));
        std::thread::spawn(move || {
            let mut buf = String::new();
            let mut err = err;
            err.read_to_string(&mut buf).ok();
            std::fs::write(&errlog, buf).ok();
        });
        Role {
            name,
            child,
            stdout: rx,
        }
    }

    /// Block (with deadline) until a stdout line contains `needle`;
    /// returns the full line.
    fn wait_for_line(&self, needle: &str, deadline: Instant) -> String {
        loop {
            let left = deadline
                .checked_duration_since(Instant::now())
                .unwrap_or_else(|| panic!("{}: timed out waiting for {needle:?}", self.name));
            match self.stdout.recv_timeout(left) {
                Ok(line) if line.contains(needle) => return line,
                Ok(_) => continue,
                Err(e) => panic!("{}: stdout closed waiting for {needle:?}: {e}", self.name),
            }
        }
    }

    /// Wait for exit (with deadline) and return (status, remaining
    /// stdout lines).
    fn join(mut self, deadline: Instant) -> (std::process::ExitStatus, Vec<String>) {
        let status = loop {
            if let Some(s) = self.child.try_wait().unwrap() {
                break s;
            }
            if Instant::now() >= deadline {
                let _ = self.child.kill();
                panic!("{}: did not exit before the deadline", self.name);
            }
            std::thread::sleep(Duration::from_millis(50));
        };
        // The reader thread may still be flushing the tail of the pipe;
        // drain until it hits EOF and drops its sender.
        let mut lines = Vec::new();
        while let Ok(line) = self.stdout.recv_timeout(Duration::from_secs(10)) {
            lines.push(line);
        }
        (status, lines)
    }

    fn kill(mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn addr_of(line: &str) -> String {
    line.rsplit(' ').next().unwrap().trim().to_string()
}

#[test]
fn tcp_cluster_survives_worker_kill() {
    let deadline = Instant::now() + DEADLINE;
    let roots = TempDir::new("cluster-tcp").unwrap();

    // Two PFS stripe servers on ephemeral ports.
    let mut pfs_addrs = Vec::new();
    let mut pfs = Vec::new();
    for i in 0..2 {
        let role = Role::spawn(
            if i == 0 { "pfs-0" } else { "pfs-1" },
            &[
                "cluster".into(),
                "pfs-server".into(),
                "--listen".into(),
                "127.0.0.1:0".into(),
                "--root".into(),
                roots.path().join(format!("pfs{i}")).display().to_string(),
            ],
        );
        pfs_addrs.push(addr_of(&role.wait_for_line("pfs-server listening on", deadline)));
        pfs.push(role);
    }
    let pfs_list = pfs_addrs.join(",");

    // Coordinator: generates 2000 records (8 objects → 8 map splits),
    // expects 2 workers, fixed epoch for a stable job id.
    let coordinator = Role::spawn(
        "coordinator",
        &[
            "cluster".into(),
            "coordinator".into(),
            "--listen".into(),
            "127.0.0.1:0".into(),
            "--workers".into(),
            "2".into(),
            "--pfs".into(),
            pfs_list.clone(),
            "--records".into(),
            "2000".into(),
            "--records-per-object".into(),
            "250".into(),
            "--reducers".into(),
            "3".into(),
            "--split-size".into(),
            "25000".into(),
            "--seed".into(),
            "42".into(),
            "--epoch".into(),
            "7".into(),
            "--grace-ms".into(),
            "60000".into(),
        ],
    );
    let coord_addr = addr_of(&coordinator.wait_for_line("coordinator listening on", deadline));

    let worker_args = |extra: &[&str]| -> Vec<String> {
        let mut v: Vec<String> = vec![
            "cluster".into(),
            "worker".into(),
            "--coordinator".into(),
            coord_addr.clone(),
            "--pfs".into(),
            pfs_list.clone(),
        ];
        v.extend(extra.iter().map(|s| s.to_string()));
        v
    };
    // The survivor runs the worker-side two-level store (`--mem-capacity`
    // > 0 tiers it over the stripe servers); the casualty stays untiered,
    // so the smoke test covers both shapes in one job.
    let survivor = Role::spawn(
        "worker-survivor",
        &worker_args(&["--mem-capacity", "16M"]),
    );
    let casualty = Role::spawn(
        "worker-casualty",
        &worker_args(&["--die-after-tasks", "1"]),
    );

    // The coordinator is the arbiter: it validates the sorted output
    // before exiting 0.
    let (status, lines) = coordinator.join(deadline);
    let stdout = lines.join("\n");
    assert!(
        status.success(),
        "coordinator failed ({status}); logs in target/cluster-logs/\n{stdout}"
    );
    assert!(
        stdout.contains("lost 1"),
        "coordinator must report the killed worker:\n{stdout}"
    );
    let reexec = lines
        .iter()
        .find(|l| l.starts_with("re-executed tasks: "))
        .unwrap_or_else(|| panic!("missing re-execution evidence:\n{stdout}"));
    assert!(
        !reexec.contains("[]"),
        "the killed worker's task must be re-executed: {reexec}"
    );
    assert!(
        stdout.contains("sorted=true"),
        "TeraValidate must pass:\n{stdout}"
    );
    let tier = lines
        .iter()
        .find(|l| l.starts_with("tier reads: "))
        .unwrap_or_else(|| panic!("missing per-tier read accounting:\n{stdout}"));
    assert!(
        !tier.contains("mem 0 B"),
        "the tiered survivor must report mem-tier hit bytes: {tier}"
    );

    let (s_status, _) = survivor.join(deadline);
    assert!(s_status.success(), "survivor worker failed ({s_status})");
    let (c_status, c_lines) = casualty.join(deadline);
    assert!(
        c_status.success(),
        "casualty exits cleanly after its injected death ({c_status})"
    );
    assert!(
        c_lines.iter().any(|l| l.contains("died (injected)")),
        "casualty must report the injected death: {c_lines:?}"
    );

    for p in pfs {
        p.kill();
    }
}
