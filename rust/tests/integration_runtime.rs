//! Integration: the full AOT bridge — artifacts/*.hlo.txt produced by
//! `make artifacts` loaded through the PJRT CPU client and executed with
//! real inputs, outputs checked against independently computed oracles.
//!
//! These tests are skipped (cleanly) if artifacts/ has not been built.

#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::path::Path;
use std::sync::OnceLock;

use tlstore::runtime::{f32_bytes, u32_bytes, Runtime};
use tlstore::util::rng::Pcg32;

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.toml").exists() {
            eprintln!("artifacts/ not built — run `make artifacts`; skipping");
            return None;
        }
        Some(Runtime::load_dir(dir).expect("load artifacts"))
    })
    .as_ref()
}

const TILES: usize = 64;
const LANE: usize = 256;
const BUCKETS: usize = 256;

fn random_keys(seed: u64) -> Vec<u32> {
    let mut rng = Pcg32::new(seed, 77);
    (0..TILES * LANE).map(|_| rng.next_u32()).collect()
}

/// Host-side oracle: per-tile stable sort + top-byte histogram.
fn sort_oracle(keys: &[u32]) -> (Vec<u32>, Vec<i32>, Vec<i32>) {
    let mut sorted = Vec::with_capacity(keys.len());
    let mut perm = Vec::with_capacity(keys.len());
    let mut hist = vec![0i32; BUCKETS];
    for tile in keys.chunks(LANE) {
        let mut idx: Vec<i32> = (0..LANE as i32).collect();
        idx.sort_by_key(|&i| (tile[i as usize], i));
        perm.extend_from_slice(&idx);
        sorted.extend(idx.iter().map(|&i| tile[i as usize]));
    }
    for &k in keys {
        hist[(k >> 24) as usize] += 1;
    }
    (sorted, perm, hist)
}

#[test]
fn platform_reports_cpu() {
    let Some(rt) = runtime() else { return };
    assert!(rt.platform().contains("cpu"), "{}", rt.platform());
    assert_eq!(rt.names(), vec!["analytics_agg", "sort_block"]);
}

#[test]
fn sort_block_matches_oracle_random() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("sort_block").unwrap();
    for seed in [1u64, 2, 3] {
        let keys = random_keys(seed);
        let out = art.call_bytes(&[&u32_bytes(&keys)]).unwrap();
        let (sorted, perm, hist) = sort_oracle(&keys);
        assert_eq!(out[0].as_u32().unwrap(), &sorted[..], "seed {seed}");
        assert_eq!(out[1].as_s32().unwrap(), &perm[..], "seed {seed}");
        assert_eq!(out[2].as_s32().unwrap(), &hist[..], "seed {seed}");
    }
}

#[test]
fn sort_block_handles_duplicates_and_extremes() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("sort_block").unwrap();
    // heavy duplicates
    let mut rng = Pcg32::new(9, 9);
    let mut keys: Vec<u32> = (0..TILES * LANE).map(|_| rng.gen_range(5)).collect();
    keys[0] = u32::MAX;
    keys[1] = 0;
    let out = art.call_bytes(&[&u32_bytes(&keys)]).unwrap();
    let (sorted, perm, hist) = sort_oracle(&keys);
    assert_eq!(out[0].as_u32().unwrap(), &sorted[..]);
    assert_eq!(out[1].as_s32().unwrap(), &perm[..]);
    assert_eq!(out[2].as_s32().unwrap(), &hist[..]);
    // histogram sums to the element count
    let total: i32 = out[2].as_s32().unwrap().iter().sum();
    assert_eq!(total as usize, TILES * LANE);
}

#[test]
fn sort_block_rejects_wrong_sizes() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("sort_block").unwrap();
    let short = vec![0u8; 16];
    assert!(art.call_bytes(&[&short]).is_err());
    assert!(art.call_bytes(&[]).is_err());
}

#[test]
fn analytics_agg_matches_oracle() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("analytics_agg").unwrap();
    const ROWS: usize = 4096;
    const COLS: usize = 8;
    let mut rng = Pcg32::new(4, 4);
    let x: Vec<f32> = (0..ROWS * COLS)
        .map(|_| (rng.gen_f64() * 200.0 - 100.0) as f32)
        .collect();
    let out = art.call_bytes(&[&f32_bytes(&x)]).unwrap();
    let stats = out[0].as_f32().unwrap(); // (4, COLS): sum,min,max,sumsq
    let mean = out[1].as_f32().unwrap();
    let var = out[2].as_f32().unwrap();

    for c in 0..COLS {
        let col: Vec<f64> = (0..ROWS).map(|r| x[r * COLS + c] as f64).collect();
        let sum: f64 = col.iter().sum();
        let min = col.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let sumsq: f64 = col.iter().map(|v| v * v).sum();
        let m = sum / ROWS as f64;
        let v = sumsq / ROWS as f64 - m * m;
        assert!((stats[c] as f64 - sum).abs() < 1.0, "col {c} sum");
        assert!((stats[COLS + c] as f64 - min).abs() < 1e-4, "col {c} min");
        assert!((stats[2 * COLS + c] as f64 - max).abs() < 1e-4, "col {c} max");
        assert!(
            (stats[3 * COLS + c] as f64 - sumsq).abs() / sumsq.max(1.0) < 1e-3,
            "col {c} sumsq"
        );
        assert!((mean[c] as f64 - m).abs() < 1e-3, "col {c} mean");
        assert!((var[c] as f64 - v).abs() / v.max(1.0) < 1e-2, "col {c} var");
    }
}

#[test]
fn concurrent_calls_are_safe() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("sort_block").unwrap();
    std::thread::scope(|s| {
        for t in 0..4 {
            s.spawn(move || {
                let keys = random_keys(100 + t);
                let out = art.call_bytes(&[&u32_bytes(&keys)]).unwrap();
                let (sorted, _, _) = sort_oracle(&keys);
                assert_eq!(out[0].as_u32().unwrap(), &sorted[..]);
            });
        }
    });
    assert!(art.calls() >= 4);
}
