//! Runs the backend-generic v2 `ObjectStore` conformance suite
//! (`tlstore::testing::conformance`) against all four backends, each
//! configured with a small geometry (64-byte stripes, 256-byte blocks)
//! so the fixed test sizes cross many stripe/block boundaries.
//!
//! What the suite proves, per backend: handle reads match whole-object
//! reads at every offset/length boundary, commits are atomic (a reader
//! racing an uncommitted writer sees the old object or `NotFound`, never
//! a prefix), aborts leave no orphan stripes/replicas/blocks, and
//! `read_at`/`read_range` clamp at EOF.

use std::path::Path;
use std::sync::Arc;
use std::thread;

use tlstore::cluster::{serve, Listener, LoopbackNet, RemotePfs};
use tlstore::storage::hdfs::HdfsLike;
use tlstore::storage::memstore::MemStore;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectReader as _, ObjectStore, ObjectWriter as _, ReadMode, WriteMode};
use tlstore::testing::conformance::{check_conformance, check_fault_conformance};
use tlstore::testing::crash::{crash_sweep, Workload};
use tlstore::testing::TempDir;

#[test]
fn memstore_conforms() {
    // plenty of capacity: conformance is about the API contract, not
    // eviction (which is covered by the memstore unit tests)
    let store = MemStore::with_shards(64 << 20, "lru", 4).unwrap();
    check_conformance(&store);
}

#[test]
fn memstore_single_shard_conforms() {
    let store = MemStore::new(64 << 20, "lfu").unwrap();
    check_conformance(&store);
}

#[test]
fn pfs_conforms() {
    let dir = TempDir::new("conf-pfs").unwrap();
    let store = Pfs::open(dir.path(), 3, 64).unwrap();
    check_conformance(&store);
}

#[test]
fn pfs_single_server_conforms() {
    let dir = TempDir::new("conf-pfs1").unwrap();
    let store = Pfs::open(dir.path(), 1, 64).unwrap();
    check_conformance(&store);
}

#[test]
fn hdfs_conforms() {
    let dir = TempDir::new("conf-hdfs").unwrap();
    let store = HdfsLike::open(dir.path(), 4, 2).unwrap();
    check_conformance(&store);
}

#[test]
fn two_level_conforms() {
    let dir = TempDir::new("conf-tls").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(1 << 20)
        .block_size(256)
        .pfs_servers(3)
        .stripe_size(64)
        .pfs_buffer(128)
        .build()
        .unwrap();
    let store = TwoLevelStore::open(cfg).unwrap();
    check_conformance(&store);
}

#[test]
fn two_level_under_eviction_pressure_conforms() {
    // a memory tier of only 4 blocks: handle reads constantly fault from
    // the PFS; the contract must hold regardless of residency
    let dir = TempDir::new("conf-tls-ev").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(1024)
        .block_size(256)
        .pfs_servers(3)
        .stripe_size(64)
        .pfs_buffer(128)
        .build()
        .unwrap();
    let store = TwoLevelStore::open(cfg).unwrap();
    check_conformance(&store);
}

/// The two-level mode-carrying handles compose with the conformance
/// guarantees: a MemOnly-committed object round-trips through TwoLevel
/// readers, and Bypass writers/readers skip the memory tier entirely.
#[test]
fn two_level_mode_handles_roundtrip() {
    let dir = TempDir::new("conf-tls-modes").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(1 << 20)
        .block_size(256)
        .pfs_servers(2)
        .stripe_size(64)
        .build()
        .unwrap();
    let store = TwoLevelStore::open(cfg).unwrap();
    let data: Vec<u8> = (0..1500u32).map(|i| (i % 251) as u8).collect();

    for (mode, key) in [
        (WriteMode::MemOnly, "m/hot"),
        (WriteMode::Bypass, "m/cold"),
        (WriteMode::WriteThrough, "m/both"),
    ] {
        let mut w = store.create_with(key, mode).unwrap();
        for chunk in data.chunks(97) {
            w.append(chunk).unwrap();
        }
        w.commit().unwrap();
        let r = store.open_with(key, ReadMode::TwoLevel).unwrap();
        let mut back = vec![0u8; data.len()];
        let mut off = 0u64;
        while (off as usize) < back.len() {
            let n = r.read_at(off, &mut back[off as usize..]).unwrap();
            assert!(n > 0);
            off += n as u64;
        }
        assert_eq!(back, data, "mode handle roundtrip for {key}");
    }
    // the MemOnly object is dirty until checkpointed
    assert_eq!(store.unpersisted(), vec!["m/hot"]);
    store.checkpoint("m/hot").unwrap();
    assert!(store.unpersisted().is_empty());
}

// ---- fault conformance ----------------------------------------------------
// Every backend wrapped in a `FaultStore` must surface injected faults as
// proper `Error` variants with no partial visibility; see
// `testing::conformance::check_fault_conformance` for the contracts.

#[test]
fn memstore_fault_conformance() {
    let store = MemStore::with_shards(64 << 20, "lru", 4).unwrap();
    check_fault_conformance(&store);
}

#[test]
fn pfs_fault_conformance() {
    let dir = TempDir::new("fault-pfs").unwrap();
    let store = Pfs::open(dir.path(), 3, 64).unwrap();
    check_fault_conformance(&store);
}

#[test]
fn hdfs_fault_conformance() {
    let dir = TempDir::new("fault-hdfs").unwrap();
    let store = HdfsLike::open(dir.path(), 4, 2).unwrap();
    check_fault_conformance(&store);
}

#[test]
fn two_level_fault_conformance() {
    let dir = TempDir::new("fault-tls").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(1 << 20)
        .block_size(256)
        .pfs_servers(3)
        .stripe_size(64)
        .pfs_buffer(128)
        .build()
        .unwrap();
    let store = TwoLevelStore::open(cfg).unwrap();
    check_fault_conformance(&store);
}

// ---- crash-at-every-boundary sweeps ---------------------------------------
// For each backend: run the scripted workload with a crash injected at
// every append/commit boundary in turn, reboot over the surviving
// directory tree, `recover()`, then assert the old-or-new-or-absent
// invariant and that no writer temps survive (`testing::crash`).

/// Fresh keys, an overwrite, a delete, and an empty object — the shapes
/// whose crash behaviour differs; chunk sizes force multi-append streams
/// crossing stripe (64 B) and block (256 B) boundaries.
fn sweep_workload() -> Workload {
    Workload::default()
        .put("s/a", 1, 700, 256)
        .put("s/b", 1, 300, 128)
        .delete("s/b")
        .put("s/a", 2, 500, 200)
        .put("s/empty", 1, 0, 64)
}

#[test]
fn memstore_crash_sweep() {
    // the memory tier is volatile: committed keys may vanish on reboot
    // (durable = false), but must never read as a prefix or resurrect
    crash_sweep(
        "mem",
        false,
        |_root: &Path| MemStore::with_shards(64 << 20, "lru", 4).unwrap(),
        &sweep_workload(),
    );
}

#[test]
fn pfs_crash_sweep() {
    crash_sweep(
        "pfs",
        true,
        |root: &Path| Pfs::open(root, 3, 64).unwrap(),
        &sweep_workload(),
    );
}

#[test]
fn hdfs_crash_sweep() {
    crash_sweep(
        "hdfs",
        true,
        |root: &Path| HdfsLike::open(root, 4, 2).unwrap(),
        &sweep_workload(),
    );
}

#[test]
fn two_level_crash_sweep() {
    crash_sweep(
        "tls",
        true,
        |root: &Path| {
            let cfg = TlsConfig::builder(root)
                .mem_capacity(1 << 20)
                .block_size(256)
                .pfs_servers(3)
                .stripe_size(64)
                .pfs_buffer(128)
                .build()
                .unwrap();
            TwoLevelStore::open(cfg).unwrap()
        },
        &sweep_workload(),
    );
}

/// Small chunks against a large-ish coalesce threshold: most appends
/// only grow the writer's carry buffer, so nearly every crash boundary
/// lands with batched-but-unflushed bytes in flight. The second
/// threshold (1 MiB) keeps *entire objects* in the carry until commit —
/// and the harness crashes *before* the inner commit runs, so the carry
/// is lost whole, exactly like `kill -9` on a buffering process.
fn coalesced_workload() -> Workload {
    Workload::default()
        .put("c/a", 1, 700, 48)
        .put("c/b", 1, 260, 96)
        .delete("c/b")
        .put("c/a", 2, 500, 64)
}

/// The coalesce thresholds the coalesced sweeps run under: one that
/// batches a handful of small appends per flush, and one that never
/// flushes before commit. (`MemStore` has no coalescing path — appends
/// land in memory directly — so it has no new boundary to sweep.)
const COALESCE_SWEEP: [usize; 2] = [256, 1 << 20];

#[test]
fn pfs_crash_sweep_with_coalesced_appends() {
    for coalesce in COALESCE_SWEEP {
        crash_sweep(
            &format!("pfs-co{coalesce}"),
            true,
            |root: &Path| {
                let mut p = Pfs::open(root, 3, 64).unwrap();
                p.append_coalesce = coalesce;
                p
            },
            &coalesced_workload(),
        );
    }
}

#[test]
fn hdfs_crash_sweep_with_coalesced_appends() {
    for coalesce in COALESCE_SWEEP {
        crash_sweep(
            &format!("hdfs-co{coalesce}"),
            true,
            |root: &Path| {
                let mut h = HdfsLike::open(root, 4, 2).unwrap();
                h.append_coalesce = coalesce;
                h
            },
            &coalesced_workload(),
        );
    }
}

#[test]
fn two_level_crash_sweep_with_coalesced_appends() {
    for coalesce in COALESCE_SWEEP {
        crash_sweep(
            &format!("tls-co{coalesce}"),
            true,
            |root: &Path| {
                let cfg = TlsConfig::builder(root)
                    .mem_capacity(1 << 20)
                    .block_size(256)
                    .pfs_servers(3)
                    .stripe_size(64)
                    .pfs_buffer(128)
                    .append_coalesce(coalesce)
                    .build()
                    .unwrap();
                TwoLevelStore::open(cfg).unwrap()
            },
            &coalesced_workload(),
        );
    }
}

#[test]
fn two_level_crash_sweep_under_eviction_pressure() {
    // a memory tier of only 4 blocks: write-through staging constantly
    // evicts and the committed objects mostly live on the PFS — the
    // invariant must hold regardless of residency
    crash_sweep(
        "tls-ev",
        true,
        |root: &Path| {
            let cfg = TlsConfig::builder(root)
                .mem_capacity(1024)
                .block_size(256)
                .pfs_servers(3)
                .stripe_size(64)
                .pfs_buffer(128)
                .build()
                .unwrap();
            TwoLevelStore::open(cfg).unwrap()
        },
        &sweep_workload(),
    );
}

// ---- remote PFS over an in-process network --------------------------------
// The striped wire client must satisfy the same contracts as the local
// backends: per-stripe staging + rename-at-commit gives atomic commits,
// aborts unlink every staged temp, and geometry-validated opens clamp
// at EOF. The two-level store layered over it (the cluster worker's
// shape) must preserve those contracts end to end.

/// `n` loopback stripe servers, each `serve()`-ing a [`MemStore`];
/// holds the listeners and threads so they can be shut down cleanly.
struct StripeServers {
    addrs: Vec<String>,
    threads: Vec<thread::JoinHandle<()>>,
    listeners: Vec<Arc<dyn Listener>>,
}

impl StripeServers {
    fn spawn(net: &LoopbackNet, n: usize) -> Self {
        let mut addrs = Vec::new();
        let mut threads = Vec::new();
        let mut listeners = Vec::new();
        for i in 0..n {
            let addr = format!("pfs{i}:7100");
            let listener: Arc<dyn Listener> = Arc::from(net.listen(&addr).unwrap());
            let store: Arc<dyn ObjectStore> = Arc::new(MemStore::new(u64::MAX, "lru").unwrap());
            let l2 = Arc::clone(&listener);
            threads.push(thread::spawn(move || {
                serve(l2, store).unwrap();
            }));
            addrs.push(addr);
            listeners.push(listener);
        }
        Self {
            addrs,
            threads,
            listeners,
        }
    }

    /// Connect a striped client to every server.
    fn client(&self, net: &LoopbackNet, stripe_size: u64) -> RemotePfs {
        RemotePfs::connect(net, &self.addrs, stripe_size).unwrap()
    }

    /// Call after dropping every client (dropping the client conns lets
    /// the per-connection server threads exit).
    fn shutdown(self) {
        for l in &self.listeners {
            l.close();
        }
        for t in self.threads {
            t.join().unwrap();
        }
    }
}

#[test]
fn remote_pfs_conforms() {
    let net = LoopbackNet::new();
    let servers = StripeServers::spawn(&net, 3);
    let store = servers.client(&net, 64);
    check_conformance(&store);
    drop(store);
    servers.shutdown();
}

#[test]
fn remote_pfs_single_server_conforms() {
    let net = LoopbackNet::new();
    let servers = StripeServers::spawn(&net, 1);
    let store = servers.client(&net, 64);
    check_conformance(&store);
    drop(store);
    servers.shutdown();
}

#[test]
fn two_level_over_remote_conforms() {
    // the cluster worker's store shape: a mem tier faulting through to
    // the striped wire client
    let net = LoopbackNet::new();
    let servers = StripeServers::spawn(&net, 3);
    let remote = servers.client(&net, 64);
    let cfg = TlsConfig::builder("conf-tls-remote")
        .mem_capacity(1 << 20)
        .block_size(256)
        .build()
        .unwrap();
    let store = TwoLevelStore::with_tier(cfg, remote).unwrap();
    check_conformance(&store);
    drop(store);
    servers.shutdown();
}

#[test]
fn two_level_over_remote_under_eviction_pressure_conforms() {
    // a 4-block memory tier: handle reads constantly fault over the wire
    let net = LoopbackNet::new();
    let servers = StripeServers::spawn(&net, 3);
    let remote = servers.client(&net, 64);
    let cfg = TlsConfig::builder("conf-tls-remote-ev")
        .mem_capacity(1024)
        .block_size(256)
        .build()
        .unwrap();
    let store = TwoLevelStore::with_tier(cfg, remote).unwrap();
    check_conformance(&store);
    drop(store);
    servers.shutdown();
}

#[test]
fn remote_pfs_fault_conformance() {
    let net = LoopbackNet::new();
    let servers = StripeServers::spawn(&net, 3);
    let store = servers.client(&net, 64);
    check_fault_conformance(&store);
    drop(store);
    servers.shutdown();
}

#[test]
fn two_level_over_remote_fault_conformance() {
    let net = LoopbackNet::new();
    let servers = StripeServers::spawn(&net, 3);
    let remote = servers.client(&net, 64);
    let cfg = TlsConfig::builder("fault-tls-remote")
        .mem_capacity(1 << 20)
        .block_size(256)
        .build()
        .unwrap();
    let store = TwoLevelStore::with_tier(cfg, remote).unwrap();
    check_fault_conformance(&store);
    drop(store);
    servers.shutdown();
}

