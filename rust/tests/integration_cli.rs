//! Integration: the `tlstore` binary itself — the §5.3 pipeline
//! (teragen → terasort → validate) driven through the CLI, plus the
//! model/sim/mountain report commands.
//!
//! Uses the binary cargo builds for this test run (`CARGO_BIN_EXE_tlstore`).

use std::process::Command;

use tlstore::testing::TempDir;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_tlstore")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .output()
        .expect("spawn tlstore");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn model_command_prints_paper_crossovers() {
    let (ok, text) = run(&["model", "--pfs-aggregate", "10000"]);
    assert!(ok, "{text}");
    assert!(text.contains("read vs pfs N=43"), "{text}");
    assert!(text.contains("vs tls(f=0.2) N=53"), "{text}");
    assert!(text.contains("write N=259"), "{text}");
}

#[test]
fn sim_command_reports_all_backends() {
    let (ok, text) = run(&["sim", "--input-gb", "4"]);
    assert!(ok, "{text}");
    for b in ["hdfs", "ofs", "tls(f=1)"] {
        assert!(text.contains(b), "missing {b}: {text}");
    }
    assert!(text.contains("map=") && text.contains("reduce="), "{text}");
}

#[test]
fn mountain_command_prints_surface() {
    let (ok, text) = run(&["mountain"]);
    assert!(ok, "{text}");
    assert!(text.contains("storage mountain"), "{text}");
    assert!(text.contains("256.0 GiB"), "{text}");
}

#[test]
fn unknown_command_and_flags_fail_loudly() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("usage:"), "{text}");
    let (ok, text) = run(&["model", "--no-such-flag", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown flag"), "{text}");
}

#[test]
fn recover_command_reports_and_repairs() {
    let dir = TempDir::new("cli-rec").unwrap();
    let root = dir.path().to_str().unwrap();
    // teragen against the PFS backend does not need artifacts
    let (ok, text) = run(&[
        "teragen", "--root", root, "--backend", "pfs", "--records", "2000",
    ]);
    assert!(ok, "teragen: {text}");
    // clean root: recover reports clean
    let (ok, text) = run(&["recover", "--root", root, "--backend", "pfs"]);
    assert!(ok, "recover: {text}");
    assert!(text.contains("clean"), "{text}");
    // plant writer debris, recover again
    std::fs::write(dir.path().join("server0").join("k.df.tmp-9"), b"junk").unwrap();
    let (ok, text) = run(&["recover", "--root", root, "--backend", "pfs"]);
    assert!(ok, "recover: {text}");
    assert!(text.contains("temps_removed=1"), "{text}");
    assert!(!dir.path().join("server0").join("k.df.tmp-9").exists());
}

#[test]
fn fault_plan_flag_injects_deterministically() {
    let dir = TempDir::new("cli-fault").unwrap();
    let root = dir.path().to_str().unwrap();
    // crash the very first create: teragen must fail with the injected
    // fault, not succeed silently
    let (ok, text) = run(&[
        "teragen",
        "--root",
        root,
        "--backend",
        "pfs",
        "--records",
        "2000",
        "--fault-plan",
        "op=create,kind=crash,after=0",
    ]);
    assert!(!ok, "teragen under a crash plan must fail: {text}");
    assert!(text.contains("injected fault"), "{text}");
    // a malformed plan is rejected up front
    let (ok, text) = run(&[
        "teragen", "--root", root, "--backend", "pfs", "--fault-plan", "kind=bogus",
    ]);
    assert!(!ok);
    assert!(text.contains("fault"), "{text}");
}

#[test]
fn job_workloads_lists_builtins() {
    let (ok, text) = run(&["job", "workloads"]);
    assert!(ok, "{text}");
    assert!(text.contains("wordcount-topk"), "{text}");
    assert!(text.contains("log-sessions"), "{text}");
    // unknown subcommand fails loudly
    let (ok, text) = run(&["job", "frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown job subcommand"), "{text}");
}

#[test]
fn job_submit_runs_a_named_pipeline_end_to_end() {
    let dir = TempDir::new("cli-job").unwrap();
    let root = dir.path().to_str().unwrap();
    // needs no artifacts on any backend; tls exercises the full path
    let (ok, text) = run(&[
        "job", "submit", "--workload", "wordcount-topk", "--root", root, "--scale", "3",
        "--seed", "7", "--reducers", "2",
    ]);
    assert!(ok, "job submit: {text}");
    assert!(text.contains("verify: top-"), "{text}");
    assert!(text.contains("shuffle namespace clean: true"), "{text}");
    // clean root: status reports nothing mid-flight
    let (ok, text) = run(&["job", "status", "--root", root]);
    assert!(ok, "job status: {text}");
    assert!(text.contains("no shuffle residue"), "{text}");
}

#[test]
fn job_submit_honors_engine_toml() {
    // the [engine] job knobs flow from TOML into the server and store
    let dir = TempDir::new("cli-job-toml").unwrap();
    let toml = dir.path().join("engine.toml");
    std::fs::write(
        &toml,
        format!(
            "[engine]\nroot = \"{}\"\nmem_capacity = \"32M\"\nblock_size = \"256k\"\n\
             max_concurrent_jobs = 2\nshuffle_spill_threshold = 0\nshuffle_chunk = \"64k\"\n",
            dir.path().join("store").display()
        ),
    )
    .unwrap();
    let (ok, text) = run(&[
        "job", "submit", "--workload", "wordcount-topk",
        "--config", toml.to_str().unwrap(), "--scale", "3", "--seed", "9",
    ]);
    assert!(ok, "job submit --config: {text}");
    assert!(text.contains("verify: top-"), "{text}");
    assert!(text.contains("shuffle namespace clean: true"), "{text}");
    // a bad config fails up front
    std::fs::write(&toml, "[engine]\nshuffle_chunk = 0\n").unwrap();
    let (ok, text) = run(&[
        "job", "submit", "--workload", "wordcount-topk", "--config", toml.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("shuffle_chunk"), "{text}");
}

#[test]
fn job_submit_concurrent_sessions() {
    let dir = TempDir::new("cli-job-sessions").unwrap();
    let root = dir.path().to_str().unwrap();
    let (ok, text) = run(&[
        "job", "submit", "--workload", "log-sessions", "--root", root, "--scale", "6",
        "--seed", "11", "--jobs", "2", "--max-jobs", "2",
    ]);
    assert!(ok, "job submit: {text}");
    assert!(text.contains("histogram ok"), "{text}");
}

#[test]
fn teragen_terasort_validate_pipeline_via_cli() {
    // runs everywhere: the sort kernel falls back to the CPU path when
    // artifacts/ is absent (the CLI prints which one it used)
    let dir = TempDir::new("cli-ts").unwrap();
    let root = dir.path().to_str().unwrap();

    let (ok, text) = run(&[
        "teragen",
        "--root",
        root,
        "--backend",
        "tls",
        "--records",
        "20000",
    ]);
    assert!(ok, "teragen: {text}");

    let (ok, text) = run(&[
        "terasort",
        "--root",
        root,
        "--backend",
        "tls",
        "--reducers",
        "4",
        "--split-size",
        "512k",
    ]);
    assert!(ok, "terasort: {text}");
    assert!(text.contains("sort kernel:"), "{text}");
    assert!(text.contains("job=terasort"), "{text}");
    assert!(text.contains("locality="), "{text}");
    assert!(text.contains("measured I/O"), "{text}");

    let (ok, text) = run(&["validate", "--root", root, "--backend", "tls"]);
    assert!(ok, "validate: {text}");
    assert!(
        text.contains("records=20000 sorted=true checksum_match=true"),
        "{text}"
    );
}

#[test]
fn bench_parity_smoke_writes_trajectory_files() {
    let dir = TempDir::new("cli-parity").unwrap();
    let out = dir.path().to_str().unwrap();
    // tiny + effectively unbounded tolerance: this asserts the plumbing
    // (runs on all four backends, measures non-zero, emits the JSON
    // files), not host-dependent throughput ratios; the CI model-parity
    // lane runs the real --smoke tolerance
    let (ok, text) = run(&[
        "bench",
        "parity",
        "--smoke",
        "--records",
        "3000",
        "--scale",
        "2",
        "--reducers",
        "2",
        "--tolerance",
        "1000000",
        "--seed",
        "20150831",
        "--out-dir",
        out,
    ]);
    assert!(ok, "bench parity: {text}");
    assert!(text.contains("model parity: OK"), "{text}");
    assert!(text.contains("terasort"), "{text}");
    let fig7 = std::fs::read_to_string(dir.join("BENCH_fig7.json")).unwrap();
    assert!(fig7.contains("\"passed\":true"), "{fig7}");
    for backend in ["\"mem\"", "\"pfs\"", "\"hdfs\"", "\"tls\""] {
        assert!(fig7.contains(backend), "missing {backend}: {fig7}");
    }
    let fig5 = std::fs::read_to_string(dir.join("BENCH_fig5.json")).unwrap();
    assert!(fig5.contains("\"ours\":43"), "{fig5}");
    assert!(!fig5.contains("\"exact\":false"), "{fig5}");

    // unknown subcommand fails loudly
    let (ok, text) = run(&["bench", "frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown bench subcommand"), "{text}");
}

#[test]
fn validate_detects_unsorted_output() {
    // validate against the *input* prefix (unsorted) must fail
    let dir = TempDir::new("cli-bad").unwrap();
    let root = dir.path().to_str().unwrap();
    let (ok, _) = run(&[
        "teragen",
        "--root",
        root,
        "--backend",
        "pfs",
        "--records",
        "5000",
    ]);
    assert!(ok);
    let (ok, text) = run(&[
        "validate",
        "--root",
        root,
        "--backend",
        "pfs",
        "--out",
        "in/", // point "output" at the unsorted input
    ]);
    assert!(!ok, "validating unsorted data must fail: {text}");
}
