//! Property tests on storage invariants (mini prop harness; proptest is
//! not in the offline crate set — see `tlstore::testing`).
//!
//! Invariants:
//! - round-trip: read(write(x)) == x for every backend, any size/mode
//! - read_range(k, o, l) == read(k)[o..o+l] clamped, for all (o, l)
//! - layout mapping: segments tile the range exactly, round-robin balance
//! - memstore: used ≤ capacity always; eviction victims carry exact bytes
//! - two-level: mem_bytes + pfs_bytes read == bytes returned
//! - crash consistency: randomized workload × randomized `FaultPlan` seed
//!   → after crash + reboot + `recover()`, every key is fully-old,
//!   fully-new, or absent, and `used ≤ capacity` still holds

use tlstore::storage::fault::{FaultPlan, FaultStore};
use tlstore::storage::layout::StripeLayout;
use tlstore::storage::memstore::MemStore;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, ReadMode, WriteMode};
use tlstore::testing::crash::{assert_no_residue, run_to_crash, verify_after_recovery, Workload};
use tlstore::testing::{proprun, PropConfig, TempDir};
use tlstore::util::rng::Pcg32;

fn cfg(cases: u32, max_size: usize) -> PropConfig {
    PropConfig {
        cases,
        max_size,
        ..Default::default()
    }
}

#[test]
fn prop_tls_roundtrip_any_size_and_mode() {
    let dir = TempDir::new("prop-rt").unwrap();
    let store = TwoLevelStore::open(
        TlsConfig::builder(dir.path())
            .mem_capacity(512 << 10)
            .block_size(8 << 10)
            .pfs_servers(3)
            .stripe_size(3000) // deliberately non-power-of-two
            .build()
            .unwrap(),
    )
    .unwrap();
    let counter = std::sync::atomic::AtomicU64::new(0);
    proprun(
        "tls-roundtrip",
        cfg(48, 40),
        |rng, size| {
            let n = rng.gen_range((size * 2048) as u32 + 1) as usize;
            let mut v = vec![0u8; n];
            rng.fill_bytes(&mut v);
            let mode = match rng.gen_range(3) {
                0 => WriteMode::MemOnly,
                1 => WriteMode::Bypass,
                _ => WriteMode::WriteThrough,
            };
            (v, mode)
        },
        |(data, mode)| {
            let key = format!(
                "k{}",
                counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            );
            store
                .write(&key, data, *mode)
                .map_err(|e| format!("write: {e}"))?;
            let back = store
                .read(&key, ReadMode::TwoLevel)
                .map_err(|e| format!("read: {e}"))?;
            if back != *data {
                return Err(format!("mismatch: {} vs {} bytes", back.len(), data.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_read_range_equals_slice() {
    let dir = TempDir::new("prop-range").unwrap();
    let store = TwoLevelStore::open(
        TlsConfig::builder(dir.path())
            .mem_capacity(1 << 20)
            .block_size(4 << 10)
            .pfs_servers(2)
            .stripe_size(1500)
            .build()
            .unwrap(),
    )
    .unwrap();
    let mut rng = Pcg32::new(42, 42);
    let mut body = vec![0u8; 100_000];
    rng.fill_bytes(&mut body);
    store.write("obj", &body, WriteMode::WriteThrough).unwrap();
    let body2 = body.clone();

    proprun(
        "range-equals-slice",
        cfg(128, 64),
        |rng, _size| {
            let off = rng.gen_range(110_000) as u64;
            let len = rng.gen_range(50_000) as usize;
            (off, len)
        },
        move |&(off, len)| {
            let got = store
                .read_range("obj", off, len, ReadMode::TwoLevel)
                .map_err(|e| format!("{e}"))?;
            let start = (off as usize).min(body2.len());
            let end = (start + len).min(body2.len());
            if got != body2[start..end] {
                return Err(format!("range ({off},{len}) mismatch"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_layout_segments_tile_exactly() {
    proprun(
        "layout-tiling",
        cfg(200, 64),
        |rng, size| {
            let stripe = rng.gen_range((size * 100) as u32) as u64 + 1;
            let servers = rng.gen_range(8) as usize + 1;
            let obj = rng.gen_range(1_000_000) as u64;
            let off = rng.gen_range(1_100_000) as u64;
            let len = rng.gen_range(500_000) as u64;
            (stripe, servers, obj, off, len)
        },
        |&(stripe, servers, obj, off, len)| {
            let l = StripeLayout::new(stripe, servers).map_err(|e| format!("{e}"))?;
            let segs = l.map_range(obj, off, len);
            let expect_end = (off + len).min(obj);
            let expect = expect_end.saturating_sub(off.min(expect_end));
            let covered: u64 = segs.iter().map(|s| s.len).sum();
            if covered != expect {
                return Err(format!("covered {covered} != {expect}"));
            }
            // contiguity + server validity
            let mut cur = off;
            for s in &segs {
                if s.object_offset != cur {
                    return Err(format!("gap at {cur}"));
                }
                if s.server >= servers {
                    return Err(format!("server {} out of range", s.server));
                }
                if s.server != l.server_of(s.stripe) {
                    return Err("server != round robin".into());
                }
                cur += s.len;
            }
            // total bytes across servers == object size
            let total: u64 = (0..servers).map(|sv| l.server_bytes(obj, sv)).sum();
            if total != obj {
                return Err(format!("server_bytes sum {total} != {obj}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_memstore_capacity_never_exceeded() {
    proprun(
        "memstore-capacity",
        cfg(64, 48),
        |rng, size| {
            let cap = rng.gen_range(64_000) as u64 + 1_000;
            let ops: Vec<(u32, u32)> = (0..size * 4)
                .map(|_| (rng.gen_range(20), rng.gen_range(cap as u32)))
                .collect();
            let policy = if rng.gen_range(2) == 0 { "lru" } else { "lfu" };
            (cap, policy, ops)
        },
        |(cap, policy, ops)| {
            let m = MemStore::new(*cap, policy).map_err(|e| format!("{e}"))?;
            for (i, &(key, len)) in ops.iter().enumerate() {
                let bytes: std::sync::Arc<[u8]> = vec![i as u8; len as usize].into();
                match m.put(&format!("k{key}"), bytes) {
                    Ok(evicted) => {
                        for (k, b) in &evicted {
                            if b.is_empty() && !k.is_empty() && *cap > 0 {
                                // zero-length victims are fine; just exercise
                            }
                        }
                    }
                    Err(tlstore::Error::OverCapacity { .. }) => {} // legal for len > cap
                    Err(e) => return Err(format!("put: {e}")),
                }
                if m.used() > *cap {
                    return Err(format!("used {} > cap {cap}", m.used()));
                }
            }
            Ok(())
        },
    );
}

/// Randomized workload + randomized `FaultPlan` seed: whatever the fault
/// (injected error or simulated crash) and wherever it lands, after a
/// reboot over the surviving tree plus `recover()`:
///
/// - every key reads fully-old, fully-new, or NotFound — never a prefix,
///   never a resurrected uncommitted write (checked byte-for-byte);
/// - no writer temp files survive anywhere under the store root;
/// - the memory tier's global capacity accountant still holds
///   (`used ≤ capacity`), including after the verification reads re-warm
///   the cache through eviction pressure.
#[test]
fn prop_crash_plus_recovery_leaves_old_new_or_absent() {
    let counter = std::sync::atomic::AtomicU64::new(0);
    proprun(
        "crash-recovery",
        cfg(24, 16),
        |rng, size| {
            // a workload of 2..=2+size steps over 3 keys, and a fault seed
            let steps = 2 + rng.gen_range(size as u32 + 1);
            let mut versions = [0u64; 3];
            let mut w = Workload::default();
            for _ in 0..steps {
                let ki = rng.gen_range(3) as usize;
                let key = format!("p/{ki}");
                if rng.gen_range(6) == 0 {
                    w = w.delete(&key);
                } else {
                    versions[ki] += 1;
                    let size = rng.gen_range(2500) as usize;
                    let chunk = 32 + rng.gen_range(400) as usize;
                    w = w.put(&key, versions[ki], size, chunk);
                }
            }
            (w, rng.next_u64())
        },
        |(workload, fault_seed)| {
            let case = counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let dir = TempDir::new(&format!("prop-crash-{case}"))
                .map_err(|e| format!("tempdir: {e}"))?;
            // a deliberately tight memory tier: staging and verification
            // reads run under constant eviction pressure
            let open = |root: &std::path::Path| {
                TwoLevelStore::open(
                    TlsConfig::builder(root)
                        .mem_capacity(4 << 10)
                        .block_size(512)
                        .pfs_servers(3)
                        .stripe_size(200)
                        .pfs_buffer(256)
                        .build()
                        .unwrap(),
                )
                .unwrap()
            };
            let outcome = {
                let faulty = FaultStore::new(open(dir.path()), FaultPlan::seeded(*fault_seed));
                run_to_crash(&faulty, workload)
            };
            let store = open(dir.path());
            store.recover().map_err(|e| format!("recover: {e}"))?;
            let ctx = format!("prop case {case} fault_seed {fault_seed:#x}");
            verify_after_recovery(&store, &outcome, true, &ctx);
            assert_no_residue(dir.path(), &ctx);
            if store.mem().used() > store.mem().capacity() {
                return Err(format!(
                    "capacity accountant violated: used {} > capacity {}",
                    store.mem().used(),
                    store.mem().capacity()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tls_tier_accounting_conserves_bytes() {
    let dir = TempDir::new("prop-acct").unwrap();
    let store = TwoLevelStore::open(
        TlsConfig::builder(dir.path())
            .mem_capacity(128 << 10)
            .block_size(16 << 10)
            .pfs_servers(2)
            .stripe_size(8 << 10)
            .build()
            .unwrap(),
    )
    .unwrap();
    let counter = std::sync::atomic::AtomicU64::new(0);
    proprun(
        "tier-accounting",
        cfg(32, 32),
        |rng, size| {
            let n = rng.gen_range((size * 8192) as u32 + 1) as usize;
            let mut v = vec![0u8; n];
            rng.fill_bytes(&mut v);
            v
        },
        |data| {
            let key = format!(
                "a{}",
                counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            );
            let before = store.stats();
            store
                .write(&key, data, WriteMode::WriteThrough)
                .map_err(|e| format!("{e}"))?;
            let got = store
                .read(&key, ReadMode::TwoLevel)
                .map_err(|e| format!("{e}"))?;
            let after = store.stats();
            let served =
                (after.mem_bytes_read - before.mem_bytes_read) + (after.pfs_bytes_read - before.pfs_bytes_read);
            if served != got.len() as u64 {
                return Err(format!("served {served} != returned {}", got.len()));
            }
            Ok(())
        },
    );
}
