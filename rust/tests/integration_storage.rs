//! Integration: cross-module storage behaviour — the two-level store with
//! its coordinator under concurrency, failure injection on the PFS tier,
//! cache-pressure semantics, and backend interchangeability via the
//! ObjectStore trait.

use std::sync::Arc;

use tlstore::coordinator::{CheckpointerConfig, Coordinator};
use tlstore::storage::hdfs::HdfsLike;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, ReadMode, WriteMode};
use tlstore::testing::TempDir;
use tlstore::util::rng::Pcg32;

fn rand_data(n: usize, seed: u64) -> Vec<u8> {
    let mut rng = Pcg32::new(seed, 1);
    let mut v = vec![0u8; n];
    rng.fill_bytes(&mut v);
    v
}

fn tls(dir: &TempDir, mem: u64) -> TwoLevelStore {
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(mem)
        .block_size(64 << 10)
        .pfs_servers(3)
        .stripe_size(16 << 10)
        .build()
        .unwrap();
    TwoLevelStore::open(cfg).unwrap()
}

#[test]
fn every_backend_honors_object_store_contract() {
    let cases: Vec<(TempDir, Box<dyn Fn(&TempDir) -> Arc<dyn ObjectStore>>)> = vec![
        (
            TempDir::new("c-tls").unwrap(),
            Box::new(|d: &TempDir| Arc::new(tls(d, 8 << 20)) as Arc<dyn ObjectStore>),
        ),
        (
            TempDir::new("c-pfs").unwrap(),
            Box::new(|d: &TempDir| Arc::new(Pfs::open(d.path(), 3, 4096).unwrap())),
        ),
        (
            TempDir::new("c-hdfs").unwrap(),
            Box::new(|d: &TempDir| Arc::new(HdfsLike::open(d.path(), 4, 2).unwrap())),
        ),
    ];
    for (dir, mk) in &cases {
        let store = mk(dir);
        let kind = store.kind();
        let a = rand_data(50_000, 1);
        let b = rand_data(1, 2);
        store.write("p/a", &a).unwrap();
        store.write("p/b", &b).unwrap();
        store.write("q/c", b"c").unwrap();

        assert_eq!(store.read("p/a").unwrap(), a, "{kind}");
        assert_eq!(store.read_range("p/a", 100, 50).unwrap(), &a[100..150], "{kind}");
        assert_eq!(store.read_range("p/a", 49_999, 10).unwrap(), &a[49_999..], "{kind}");
        assert_eq!(store.size("p/a").unwrap(), 50_000, "{kind}");
        assert!(store.exists("p/b"), "{kind}");
        assert_eq!(store.list("p/"), vec!["p/a", "p/b"], "{kind}");
        // overwrite
        store.write("p/a", &b).unwrap();
        assert_eq!(store.read("p/a").unwrap(), b, "{kind}");
        // delete idempotent
        store.delete("p/a").unwrap();
        store.delete("p/a").unwrap();
        assert!(!store.exists("p/a"), "{kind}");
        assert!(store.read("p/a").is_err(), "{kind}");
    }
}

#[test]
fn concurrent_mixed_workload_on_tls() {
    let dir = TempDir::new("conc").unwrap();
    let store = Arc::new(tls(&dir, 1 << 20)); // tight memory: force eviction
    let mut handles = Vec::new();
    for t in 0..6u64 {
        let store = Arc::clone(&store);
        handles.push(std::thread::spawn(move || {
            let mut rng = Pcg32::for_task(9, t);
            for i in 0..30 {
                let key = format!("t{t}/obj{i}");
                let body = rand_data((rng.gen_range(120_000) + 1) as usize, t * 100 + i);
                let mode = match i % 3 {
                    0 => WriteMode::WriteThrough,
                    1 => WriteMode::Bypass,
                    _ => WriteMode::MemOnly,
                };
                store.write(&key, &body, mode).unwrap();
                let back = store.read(&key, ReadMode::TwoLevel).unwrap();
                assert_eq!(back, body, "{key}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // memory tier never exceeded capacity
    assert!(store.mem().used() <= 1 << 20);
    // every object still fully readable after the storm
    for t in 0..6u64 {
        for i in 0..30 {
            let key = format!("t{t}/obj{i}");
            assert!(store.read(&key, ReadMode::TwoLevel).is_ok(), "{key}");
        }
    }
}

#[test]
fn pfs_server_loss_is_detected() {
    let dir = TempDir::new("fault").unwrap();
    let store = tls(&dir, 8 << 20);
    let body = rand_data(200_000, 3);
    store.write("victim", &body, WriteMode::WriteThrough).unwrap();
    store.evict_object("victim").unwrap();

    // destroy one PFS server directory (data-node failure)
    let server0 = dir.path().join("pfs").join("server0");
    std::fs::remove_dir_all(&server0).unwrap();

    let err = store.read("victim", ReadMode::TwoLevel).unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.contains("server") || msg.contains("No such file") || msg.contains("i/o"),
        "unexpected error: {msg}"
    );
}

#[test]
fn corruption_on_pfs_surfaces_as_checksum_error() {
    let dir = TempDir::new("corrupt").unwrap();
    let store = tls(&dir, 8 << 20);
    let body = rand_data(100_000, 4);
    store.write("c", &body, WriteMode::WriteThrough).unwrap();
    store.evict_object("c").unwrap();

    // flip one byte in one stripe file
    let server1 = dir.path().join("pfs").join("server1");
    let df = std::fs::read_dir(&server1)
        .unwrap()
        .flatten()
        .find(|e| e.file_name().to_string_lossy().starts_with("c."))
        .expect("datafile");
    let mut bytes = std::fs::read(df.path()).unwrap();
    bytes[10] ^= 0xFF;
    std::fs::write(df.path(), bytes).unwrap();

    // whole-object bypass read checks the object CRC
    let err = store.read("c", ReadMode::Bypass).unwrap_err();
    assert!(matches!(err, tlstore::Error::ChecksumMismatch { .. }), "{err}");
}

#[test]
fn coordinator_survives_write_burst_with_tight_backpressure() {
    let dir = TempDir::new("burst").unwrap();
    let store = Arc::new(tls(&dir, 2 << 20));
    let coord = Coordinator::new(
        Arc::clone(&store),
        CheckpointerConfig {
            max_pending: 4,
            ..Default::default()
        },
    );
    for i in 0..64 {
        coord
            .write_async(&format!("burst/{i}"), &rand_data(30_000, i))
            .unwrap();
    }
    coord.flush().unwrap();
    let stats = coord.checkpointer().stats();
    assert_eq!(stats.completed, 64);
    assert!(stats.backpressure_events > 0);
    for i in 0..64 {
        assert_eq!(
            store.read(&format!("burst/{i}"), ReadMode::Bypass).unwrap(),
            rand_data(30_000, i)
        );
    }
    coord.shutdown().unwrap();
}

#[test]
fn restart_recovers_pfs_state_and_cold_cache_warms() {
    let dir = TempDir::new("restart").unwrap();
    let bodies: Vec<Vec<u8>> = (0..5).map(|i| rand_data(80_000, 50 + i)).collect();
    {
        let store = tls(&dir, 8 << 20);
        for (i, b) in bodies.iter().enumerate() {
            store.write(&format!("keep/{i}"), b, WriteMode::WriteThrough).unwrap();
        }
    }
    let store = tls(&dir, 8 << 20);
    assert_eq!(store.list("keep/").len(), 5);
    // cold: first reads hit the PFS tier
    for (i, b) in bodies.iter().enumerate() {
        assert_eq!(&store.read(&format!("keep/{i}"), ReadMode::TwoLevel).unwrap(), b);
    }
    assert!(store.stats().pfs_bytes_read >= 5 * 80_000);
    // warm: repeat reads come from memory
    let before = store.stats().mem_bytes_read;
    for i in 0..5 {
        store.read(&format!("keep/{i}"), ReadMode::TwoLevel).unwrap();
    }
    assert!(store.stats().mem_bytes_read >= before + 5 * 80_000);
}

/// The tentpole stress test: 8 threads of mixed WriteThrough writes and
/// TwoLevel reads against one store with the lock-striped memory tier and
/// dual-leg write-through enabled. Asserts:
/// - read-your-writes: a write that returned is immediately readable, in
///   full, by the writing thread;
/// - cross-thread visibility: objects written in phase 1 are readable by
///   every other thread during the phase-2 storm;
/// - the capacity invariant: the memory tier's global accountant never
///   exceeds `mem_capacity`, sampled continuously while the storm runs.
#[test]
fn stress_sharded_writethrough_read_your_writes_and_capacity() {
    const THREADS: u64 = 8;
    const PHASE1: u64 = 16;
    const PHASE2: u64 = 8;
    const CAP: u64 = 2 << 20;

    fn body_of(t: u64, i: u64) -> Vec<u8> {
        let n = 40_000 + ((t * 31 + i * 17) % 90_000) as usize;
        rand_data(n, t * 1_000 + i)
    }

    let dir = TempDir::new("stress").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(CAP)
        .block_size(64 << 10)
        .pfs_servers(4)
        .stripe_size(16 << 10)
        .mem_shards(8)
        .concurrent_writethrough(true)
        .build()
        .unwrap();
    let store = Arc::new(TwoLevelStore::open(cfg).unwrap());

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let sampler = {
        let store = Arc::clone(&store);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut max_seen = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                max_seen = max_seen.max(store.mem().used());
                std::thread::yield_now();
            }
            max_seen
        })
    };

    // phase 1: every thread writes its own objects and reads each back
    // immediately (read-your-writes under the dual-leg write path)
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let store = Arc::clone(&store);
            s.spawn(move || {
                for i in 0..PHASE1 {
                    let key = format!("t{t}/p1/{i}");
                    let body = body_of(t, i);
                    store.write(&key, &body, WriteMode::WriteThrough).unwrap();
                    let back = store.read(&key, ReadMode::TwoLevel).unwrap();
                    assert_eq!(back, body, "read-your-writes broken for {key}");
                }
            });
        }
    });

    // phase 2: keep writing while every thread also reads its neighbour's
    // phase-1 objects (cross-thread visibility under concurrent I/O)
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let store = Arc::clone(&store);
            s.spawn(move || {
                let peer = (t + 1) % THREADS;
                for i in 0..PHASE2 {
                    let key = format!("t{t}/p2/{i}");
                    let body = body_of(t, 1_000 + i);
                    store.write(&key, &body, WriteMode::WriteThrough).unwrap();
                    assert_eq!(
                        store.read(&key, ReadMode::TwoLevel).unwrap(),
                        body,
                        "read-your-writes broken for {key}"
                    );
                    let peer_key = format!("t{peer}/p1/{}", i % PHASE1);
                    assert_eq!(
                        store.read(&peer_key, ReadMode::TwoLevel).unwrap(),
                        body_of(peer, i % PHASE1),
                        "cross-thread read broken for {peer_key}"
                    );
                }
            });
        }
    });

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let max_seen = sampler.join().unwrap();
    assert!(
        max_seen <= CAP,
        "memory tier accountant exceeded capacity: {max_seen} > {CAP}"
    );
    assert!(store.mem().used() <= CAP);

    // everything written in the storm is still fully readable
    for t in 0..THREADS {
        for i in 0..PHASE1 {
            let key = format!("t{t}/p1/{i}");
            assert_eq!(store.read(&key, ReadMode::TwoLevel).unwrap(), body_of(t, i), "{key}");
        }
        for i in 0..PHASE2 {
            let key = format!("t{t}/p2/{i}");
            assert_eq!(
                store.read(&key, ReadMode::TwoLevel).unwrap(),
                body_of(t, 1_000 + i),
                "{key}"
            );
        }
    }
    assert_eq!(store.mem().shards(), 8);
}

#[test]
fn memonly_data_larger_than_memory_spills_and_survives() {
    let dir = TempDir::new("spill").unwrap();
    let store = tls(&dir, 256 << 10); // 4 blocks of 64 KiB
    let bodies: Vec<Vec<u8>> = (0..8).map(|i| rand_data(128 << 10, 80 + i)).collect();
    for (i, b) in bodies.iter().enumerate() {
        store.write(&format!("big/{i}"), b, WriteMode::MemOnly).unwrap();
    }
    assert!(store.stats().dirty_spills > 0, "eviction must have spilled");
    for (i, b) in bodies.iter().enumerate() {
        assert_eq!(&store.read(&format!("big/{i}"), ReadMode::TwoLevel).unwrap(), b, "obj {i}");
    }
    // checkpoint everything; dirty namespace must drain
    for key in store.unpersisted() {
        store.checkpoint(&key).unwrap();
    }
    assert!(store.unpersisted().is_empty());
    assert!(store.pfs().list(".dirty/").is_empty());
}
