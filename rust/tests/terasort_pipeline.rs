//! Property: seeded TeraGen → JobServer TeraSort → TeraValidate
//! round-trips on **all four backends** at small scale — sorted order,
//! record count, and the order-insensitive checksum are preserved, the
//! shuffle really spills through `.shuffle/`, and the namespace is clean
//! afterwards. Includes a tight-memory TwoLevelStore configuration whose
//! memory tier cannot hold the job, so shuffle spills force eviction and
//! dirty-spill traffic mid-sort.
//!
//! Seeds derive from `testing::master_seed()` — reproduce any failure
//! with `TLSTORE_SEED=<seed> cargo test --test terasort_pipeline` (every
//! assertion message carries the case context).

#![allow(clippy::print_stdout, clippy::print_stderr)]

use std::sync::Arc;

use tlstore::mapreduce::{JobServer, JobServerConfig};
use tlstore::storage::hdfs::HdfsLike;
use tlstore::storage::memstore::MemStore;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, SHUFFLE_NS};
use tlstore::terasort::{
    input_checksum, run_terasort, teragen, teravalidate, SortKernel, RECORD_SIZE,
};
use tlstore::testing::{master_seed, TempDir};
use tlstore::util::rng::Pcg32;

const BACKENDS: [&str; 4] = ["mem", "pfs", "hdfs", "tls"];

fn build(backend: &str, dir: &TempDir, tight_mem: bool) -> Arc<dyn ObjectStore> {
    match backend {
        "mem" => Arc::new(MemStore::new(u64::MAX, "lru").unwrap()),
        "pfs" => Arc::new(Pfs::open(dir.path(), 3, 64 << 10).unwrap()),
        "hdfs" => Arc::new(HdfsLike::open(dir.path(), 4, 3).unwrap()),
        "tls" => {
            let cfg = TlsConfig::builder(dir.path())
                // tight: the memory tier holds ~1/4 of even a small job,
                // so write-through staging + shuffle spills keep evicting
                .mem_capacity(if tight_mem { 48 << 10 } else { 32 << 20 })
                .block_size(if tight_mem { 4 << 10 } else { 1 << 20 })
                .pfs_servers(3)
                .stripe_size(if tight_mem { 3 << 10 } else { 64 << 10 })
                .build()
                .unwrap();
            Arc::new(TwoLevelStore::open(cfg).unwrap())
        }
        other => panic!("unknown backend {other}"),
    }
}

/// One seeded round-trip on one backend; panics with `ctx` on violation.
fn roundtrip(backend: &str, records: u64, reducers: u32, seed: u64, tight_mem: bool, ctx: &str) {
    let dir = TempDir::new(&format!("ts-prop-{backend}")).unwrap();
    let store = build(backend, &dir, tight_mem);

    let written =
        teragen(store.as_ref(), "in/", records, records / 3 + 1, seed).unwrap();
    assert_eq!(written, records * RECORD_SIZE as u64, "{ctx}: teragen bytes");
    let (in_count, in_sum) = input_checksum(store.as_ref(), "in/").unwrap();

    let server = JobServer::new(
        Arc::clone(&store),
        JobServerConfig {
            workers: 2,
            nodes: 2,
            containers_per_node: 2,
            max_concurrent_jobs: 1,
            shuffle_spill_threshold: 0, // every run through .shuffle/
            shuffle_chunk: 4 << 10,     // small windows: exercise reassembly
            ..JobServerConfig::default()
        },
    );
    let stats = run_terasort(
        &server,
        Arc::new(SortKernel::Cpu),
        "in/",
        "out/",
        reducers,
        8 << 10, // many small splits
        true,
    )
    .unwrap_or_else(|e| panic!("{ctx}: terasort failed: {e}"));
    server.shutdown().unwrap();

    assert!(stats.spilled_runs() > 0, "{ctx}: shuffle must spill");
    assert!(
        store.list(SHUFFLE_NS).is_empty(),
        "{ctx}: shuffle residue left behind"
    );

    let report = teravalidate(store.as_ref(), "out/").unwrap();
    assert!(report.sorted, "{ctx}: output not globally sorted");
    assert_eq!(report.records, in_count, "{ctx}: records lost or duplicated");
    assert_eq!(report.checksum, in_sum, "{ctx}: checksum drifted");
}

#[test]
fn seeded_roundtrips_across_all_backends() {
    let master = master_seed();
    eprintln!("terasort round-trip property: TLSTORE_SEED={master}");
    for case in 0..3u64 {
        let case_seed = master ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg32::new(case_seed, 0x7E5A);
        // 120..~1400 records, 1..6 reducers — small but irregular, so
        // object boundaries, split edges, and partition skew all move
        let records = 120 + rng.gen_range(1280) as u64;
        let reducers = 1 + rng.gen_range(5);
        for backend in BACKENDS {
            let ctx = format!(
                "TLSTORE_SEED={master} case {case} ({backend}, records={records}, reducers={reducers})"
            );
            roundtrip(backend, records, reducers, case_seed, false, &ctx);
        }
    }
}

#[test]
fn tight_memory_two_level_spills_and_still_sorts() {
    let master = master_seed();
    eprintln!("tight-memory terasort: TLSTORE_SEED={master}");
    // 2000 records = 200 KB through a 48 KB memory tier: the shuffle
    // working set alone exceeds the tier, so spills must evict and the
    // PFS leg carries the job — correctness must not depend on residency
    let ctx = format!("TLSTORE_SEED={master} tight-memory tls");
    roundtrip("tls", 2_000, 4, master, true, &ctx);
}
