//! Golden pins for the §4 models: eqs. (1)–(7) on the paper's published
//! Palmetto constants, the §4.5 Figure-5 crossover points, and sampled
//! points of the aggregate curves — all as *literal* expected values, so
//! a refactor of `model/mod.rs` cannot silently drift the curves the
//! parity harness and the benches compare against.
//!
//! Values come straight from the paper (§4.5, §5.1, Figure 5) or are
//! hand-computed once from its constants (ν = 6267, ρ = 1170, μ_r = 237,
//! μ_w = 116, Palmetto: μ = 60, μ′ = 400/200, N = 16, M = 2).

use tlstore::model::{CaseStudyParams, ClusterParams};

fn close(got: f64, want: f64, rel: f64, what: &str) {
    assert!(
        (got - want).abs() <= want.abs() * rel,
        "{what}: got {got}, golden {want} (rel tol {rel})"
    );
}

// ---- eqs. (1)–(7) on the Palmetto §5.1 testbed --------------------------

#[test]
fn golden_eq1_hdfs_read() {
    let p = ClusterParams::palmetto();
    // local branch: the compute node's SATA disk
    assert_eq!(p.hdfs_read_local(), 60.0);
    // remote branch still binds on the disk, not the 1170 MB/s NIC
    assert_eq!(p.hdfs_read_remote(), 60.0);
}

#[test]
fn golden_eq2_hdfs_write() {
    // three synchronous copies: μ/3 = 20 MB/s binds
    assert_eq!(ClusterParams::palmetto().hdfs_write(), 20.0);
}

#[test]
fn golden_eq3_ofs_read_write() {
    let p = ClusterParams::palmetto();
    // (M/N)·μ′_r = 2·400/16 = 50; (M/N)·μ′_w = 2·200/16 = 25
    close(p.ofs_read(), 50.0, 1e-12, "ofs_read");
    close(p.ofs_write(), 25.0, 1e-12, "ofs_write");
    // and the N-scaling shape: doubling N halves the per-node share
    close(p.with_n(32).ofs_read(), 25.0, 1e-12, "ofs_read @N=32");
}

#[test]
fn golden_eq4_eq5_tachyon() {
    let p = ClusterParams::palmetto();
    assert_eq!(p.tachyon_read_local(), 6267.0);
    assert_eq!(p.tachyon_read_remote(), 1170.0); // NIC binds remotely
    assert_eq!(p.tachyon_write(), 6267.0);
}

#[test]
fn golden_eq6_tls_write() {
    // min(ν, q_w_OFS) = 25 MB/s: the synchronous PFS leg bounds it
    assert_eq!(ClusterParams::palmetto().tls_write(), 25.0);
}

#[test]
fn golden_eq7_tls_read_curve() {
    let p = ClusterParams::palmetto();
    // hand-computed harmonic means at ν = 6267, q_r_OFS = 50:
    //   f=0.2 → 1/(0.2/6267 + 0.8/50) = 62.376
    //   f=0.5 → 1/(0.5/6267 + 0.5/50) = 99.208
    //   f=0.8 → 1/(0.8/6267 + 0.2/50) = 242.268
    close(p.tls_read(0.0), 50.0, 1e-12, "tls_read f=0");
    close(p.tls_read(0.2), 62.376, 1e-4, "tls_read f=0.2");
    close(p.tls_read(0.5), 99.208, 1e-4, "tls_read f=0.5");
    close(p.tls_read(0.8), 242.268, 1e-4, "tls_read f=0.8");
    close(p.tls_read(1.0), 6267.0, 1e-12, "tls_read f=1");
}

// ---- §4.5 Figure-5 crossover points, exactly the paper's ----------------

#[test]
fn golden_fig5_crossovers_at_10gbs() {
    let m = CaseStudyParams::new(10_000.0);
    assert_eq!(m.crossover_read_vs_pfs(), 43);
    assert_eq!(m.crossover_read_vs_tls(0.2), 53);
    assert_eq!(m.crossover_read_vs_tls(0.5), 83);
    assert_eq!(m.crossover_write(), 259);
}

#[test]
fn golden_fig5_crossovers_at_50gbs() {
    let m = CaseStudyParams::new(50_000.0);
    assert_eq!(m.crossover_read_vs_pfs(), 211);
    assert_eq!(m.crossover_read_vs_tls(0.2), 262);
    assert_eq!(m.crossover_read_vs_tls(0.5), 414);
    assert_eq!(m.crossover_write(), 1294);
}

#[test]
fn golden_fig5_asymptotic_gains() {
    // paper: +25% at f=0.2 (10 → 12.5 GB/s), ~+95% at f=0.5 (10 → 19.6).
    // Our exact curve values, pinned tightly: 1.24975 and 1.99840.
    let m = CaseStudyParams::new(10_000.0);
    close(m.tls_asymptotic_gain(0.2, 2000), 1.249_75, 1e-4, "gain f=0.2");
    close(m.tls_asymptotic_gain(0.5, 2000), 1.998_40, 1e-4, "gain f=0.5");
}

// ---- sampled aggregate-curve points (the series Figure 5 plots) ---------

#[test]
fn golden_fig5_curve_samples_at_10gbs() {
    let m = CaseStudyParams::new(10_000.0);
    // HDFS aggregate read is linear in N at μ_r = 237
    close(m.hdfs_read_aggregate(1), 237.0, 1e-12, "hdfs_read N=1");
    close(m.hdfs_read_aggregate(43), 10_191.0, 1e-12, "hdfs_read N=43");
    // PFS aggregate saturates at B once N·ρ exceeds it: 10000/1170 ≈ 8.5
    close(m.pfs_aggregate_throughput(8), 9_360.0, 1e-12, "pfs N=8");
    close(m.pfs_aggregate_throughput(16), 10_000.0, 1e-12, "pfs N=16");
    close(m.pfs_aggregate_throughput(2000), 10_000.0, 1e-12, "pfs N=2000");
    // HDFS aggregate write: N·min(μ_w/3, ρ/2) = N·38.667
    close(m.hdfs_write_aggregate(3), 116.0, 1e-9, "hdfs_write N=3");
    close(m.hdfs_write_aggregate(259), 10_014.67, 1e-4, "hdfs_write N=259");
    // TLS aggregate read at the saturated end approaches B/(1−f)
    close(m.tls_read_aggregate(2000, 0.2), 12_497.5, 1e-3, "tls f=0.2 N=2000");
    close(m.tls_read_aggregate(2000, 0.5), 19_984.0, 1e-3, "tls f=0.5 N=2000");
    // and the write curve is the PFS curve (eq. 6)
    close(m.tls_write_aggregate(16), 10_000.0, 1e-12, "tls_write N=16");
}

#[test]
fn golden_single_node_mapping() {
    // the parity harness' single-host collapse: pinned so the measured
    // comparisons can't silently change meaning
    let p = ClusterParams::single_node(500.0, 300.0, 5000.0);
    assert_eq!(p.hdfs_read_local(), 500.0);
    close(p.hdfs_write(), 100.0, 1e-12, "hdfs_write = μ_w/3");
    assert_eq!(p.ofs_read(), 500.0);
    assert_eq!(p.ofs_write(), 300.0);
    assert_eq!(p.tls_write(), 300.0);
    close(
        p.tls_read(0.5),
        1.0 / (0.5 / 5000.0 + 0.5 / 500.0),
        1e-12,
        "tls_read f=0.5",
    );
}
