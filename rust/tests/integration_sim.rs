//! Integration: simulator vs analytic model — the two §4 artifacts must
//! agree with each other, and the simulated Figure 5/7 shapes must match
//! the paper's qualitative claims across a parameter sweep (not just the
//! single calibrated point the unit tests pin).

use tlstore::model::{CaseStudyParams, ClusterParams};
use tlstore::sim::{simulate_terasort, BackendKind, ClusterSim, SimConstants, Simulator, Stage, Task};

/// Per-node read throughput measured by simulating N concurrent readers.
fn sim_read_per_node(backend: BackendKind, n: usize, m: usize) -> f64 {
    let c = ClusterSim::new(n, m, 1, SimConstants::default());
    let sim = Simulator::new(c.resources.clone(), vec![1; n]);
    let d = 512.0;
    let tasks: Vec<Task> = (0..n)
        .map(|i| Task {
            node: i,
            stages: vec![Stage {
                flows: c.read_flows(backend, i, d),
            }],
        })
        .collect();
    let out = sim.run(tasks).unwrap();
    d / out.makespan
}

#[test]
fn sim_matches_model_eq3_across_geometries() {
    for (n, m) in [(4usize, 1usize), (8, 2), (16, 2), (32, 4), (64, 2)] {
        let model = ClusterParams::palmetto().with_n(n as u32);
        let model = ClusterParams { m: m as u32, ..model };
        let sim = sim_read_per_node(BackendKind::Ofs, n, m);
        let expect = model.ofs_read();
        let err = (sim - expect).abs() / expect;
        assert!(err < 0.10, "N={n} M={m}: sim {sim:.1} vs model {expect:.1}");
    }
}

#[test]
fn sim_matches_model_eq7_across_f() {
    let p = ClusterParams::palmetto();
    for f_pct in [0u8, 25, 50, 75, 100] {
        let sim = sim_read_per_node(BackendKind::Tls { f_pct }, 16, 2);
        let expect = p.tls_read(f_pct as f64 / 100.0);
        let err = (sim - expect).abs() / expect;
        assert!(err < 0.12, "f={f_pct}%: sim {sim:.1} vs model {expect:.1}");
    }
}

#[test]
fn tls_always_beats_bare_pfs_on_reads() {
    // the paper's core claim: for any residency f > 0, two-level ≥ OFS
    for f_pct in [10u8, 30, 60, 90] {
        for (n, m) in [(8usize, 2usize), (16, 2), (32, 4)] {
            let tls = sim_read_per_node(BackendKind::Tls { f_pct }, n, m);
            let ofs = sim_read_per_node(BackendKind::Ofs, n, m);
            assert!(
                tls > ofs * 0.99,
                "f={f_pct}% N={n} M={m}: tls {tls:.1} ≤ ofs {ofs:.1}"
            );
        }
    }
}

#[test]
fn fig5_crossover_shape_holds_in_simulation() {
    // HDFS aggregate read grows with N; PFS is flat — verify the ordering
    // flips somewhere between N=4 and N=64 with a small PFS (M=1)
    let mut flipped = false;
    let mut last_hdfs_smaller = true;
    for n in [4usize, 8, 16, 32, 64] {
        let hdfs_agg = sim_read_per_node(BackendKind::Hdfs, n, 1) * n as f64;
        let ofs_agg = sim_read_per_node(BackendKind::Ofs, n, 1) * n as f64;
        let hdfs_smaller = hdfs_agg < ofs_agg;
        if last_hdfs_smaller && !hdfs_smaller {
            flipped = true;
        }
        last_hdfs_smaller = hdfs_smaller;
    }
    assert!(flipped, "HDFS must overtake the PFS as N grows (Figure 5)");
}

#[test]
fn fig7_full_matrix_ordering_is_stable() {
    // across data sizes and container counts, the mapper ordering
    // TLS < OFS < HDFS (time) must hold
    for gb in [4.0, 16.0] {
        for containers in [8usize, 16] {
            let hdfs = simulate_terasort(BackendKind::Hdfs, 16, 2, containers, gb, SimConstants::default()).unwrap();
            let ofs = simulate_terasort(BackendKind::Ofs, 16, 2, containers, gb, SimConstants::default()).unwrap();
            let tls = simulate_terasort(BackendKind::Tls { f_pct: 100 }, 16, 2, containers, gb, SimConstants::default()).unwrap();
            assert!(
                tls.map_time < ofs.map_time && ofs.map_time < hdfs.map_time,
                "gb={gb} c={containers}: tls {:.1} ofs {:.1} hdfs {:.1}",
                tls.map_time,
                ofs.map_time,
                hdfs.map_time
            );
        }
    }
}

#[test]
fn reduce_phase_scales_with_data_nodes_monotonically() {
    let mut last = f64::INFINITY;
    for m in [2usize, 4, 6, 8, 12] {
        let r = simulate_terasort(BackendKind::Tls { f_pct: 100 }, 16, m, 16, 16.0, SimConstants::default()).unwrap();
        assert!(
            r.reduce_time <= last * 1.001,
            "reduce time must not increase with data nodes (m={m})"
        );
        last = r.reduce_time;
    }
}

#[test]
fn case_study_params_internally_consistent() {
    // the §4.5 parameterization must agree with its own general form as
    // the PFS aggregate becomes the binding term
    let cs = CaseStudyParams::new(10_000.0);
    for n in [50u32, 100, 500] {
        let per_node = cs.pfs_per_node(n);
        assert!((per_node - (10_000.0 / n as f64).min(1170.0)).abs() < 1e-9);
        // TLS read per node must interpolate between PFS and RAM
        let tls = cs.tls_read_aggregate(n, 0.5) / n as f64;
        assert!(tls > per_node && tls < 6267.0, "n={n} tls={tls}");
    }
}
