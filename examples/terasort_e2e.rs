//! End-to-end driver (DESIGN.md headline): TeraGen → TeraSort →
//! TeraValidate on real data through the Job API (JobServer + spilled
//! shuffle) over the real storage engines — run against all three
//! backends the paper compares (HDFS-like, PFS-only, two-level),
//! reporting per-phase wall clock and throughput. The mapper uses the
//! AOT-compiled Pallas sort kernel via PJRT when `make artifacts` has
//! run, and the portable CPU sort otherwise.
//!
//! Run: `cargo run --release --example terasort_e2e [-- --records N]`

use std::path::Path;
use std::sync::Arc;

use tlstore::cli::Args;
use tlstore::config::Backend;
use tlstore::mapreduce::{JobServer, JobServerConfig};
use tlstore::storage::hdfs::HdfsLike;
use tlstore::storage::pfs::Pfs;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{prefix_bytes, ObjectReader as _, ObjectStore};
use tlstore::terasort::{
    input_checksum, run_terasort, teragen, teravalidate, SortKernel, RECORD_SIZE,
};
use tlstore::testing::TempDir;

fn store_for(backend: Backend, dir: &TempDir) -> tlstore::Result<Arc<dyn ObjectStore>> {
    Ok(match backend {
        Backend::TwoLevel => {
            let cfg = TlsConfig::builder(dir.path())
                .mem_capacity(512 << 20)
                .block_size(4 << 20)
                .pfs_servers(4)
                .stripe_size(1 << 20)
                .build()?;
            Arc::new(TwoLevelStore::open(cfg)?)
        }
        Backend::Pfs => Arc::new(Pfs::open(dir.path(), 4, 1 << 20)?),
        Backend::Hdfs => Arc::new(HdfsLike::open(dir.path(), 4, 3)?),
    })
}

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let records = args.get_parse("records", 200_000u64)?; // 20 MB default
    let reducers = args.get_parse("reducers", 8u32)?;
    args.finish()?;

    let kernel = SortKernel::auto(Path::new("artifacts"));
    println!("sort kernel: {}", kernel.name());
    println!(
        "workload: {} records ({} MB), {} reducers\n",
        records,
        records * RECORD_SIZE as u64 / 1_000_000,
        reducers
    );
    println!(
        "{:<8} {:>10} {:>12} {:>12} {:>12} {:>12}  {}",
        "backend", "gen s", "map s", "map MB/s", "reduce s", "red MB/s", "validated"
    );

    let mut map_times = std::collections::BTreeMap::new();
    for backend in [Backend::Hdfs, Backend::Pfs, Backend::TwoLevel] {
        let dir = TempDir::new(&format!("ts-e2e-{}", backend.name())).unwrap();
        let store = store_for(backend, &dir)?;

        let t = std::time::Instant::now();
        // teragen streams each partition through a writer handle
        // (create/append/commit) — no whole-object buffers
        teragen(store.as_ref(), "in/", records, records / 8 + 1, 42)?;
        let gen_s = t.elapsed().as_secs_f64();

        // v2 surface: stat-backed accounting + a streamed peek at the
        // first input record through a reader handle
        let in_bytes = prefix_bytes(store.as_ref(), "in/")?;
        debug_assert_eq!(in_bytes, records * RECORD_SIZE as u64);
        if let Some(first) = store.list("in/").first() {
            let meta = store.stat(first)?;
            let reader = store.open(first)?;
            let mut head = vec![0u8; RECORD_SIZE];
            let n = reader.read_at(0, &mut head)?;
            assert_eq!(n, RECORD_SIZE.min(meta.size as usize));
        }
        let (in_count, in_sum) = input_checksum(store.as_ref(), "in/")?;

        // the Job API path: a one-job server over this backend; the
        // shuffle spills through `.shuffle/` on the store under test
        let server = JobServer::new(Arc::clone(&store), JobServerConfig::default());
        let stats = run_terasort(
            &server,
            Arc::clone(&kernel),
            "in/",
            "out/",
            reducers,
            4 << 20,
            true,
        )?;
        server.shutdown()?;

        let report = teravalidate(store.as_ref(), "out/")?;
        let ok = report.sorted && report.records == in_count && report.checksum == in_sum;
        let js = stats.to_job_stats();
        println!(
            "{:<8} {:>10.2} {:>12.2} {:>12.1} {:>12.2} {:>12.1}  {}",
            backend.name(),
            gen_s,
            js.map_time.as_secs_f64(),
            js.map_read_mbs(),
            js.reduce_time.as_secs_f64(),
            js.reduce_write_mbs(),
            if ok { "OK" } else { "FAILED" }
        );
        if !ok {
            return Err(tlstore::Error::Job(format!(
                "{} validation failed",
                backend.name()
            )));
        }
        map_times.insert(backend.name(), js.map_time.as_secs_f64());
    }

    // the paper's Figure 7(f) shape: the TLS mapper phase should beat the
    // disk-replicated baseline at equal data (hot memory tier)
    let tls = map_times["tls"];
    let hdfs = map_times["hdfs"];
    let pfs = map_times["pfs"];
    println!(
        "\nmap-phase speedup of two-level: {:.2}× vs hdfs, {:.2}× vs pfs (paper at scale: 5.4×, 4.2×)",
        hdfs / tls,
        pfs / tls
    );
    println!("terasort_e2e OK");
    Ok(())
}
