//! The storage mountain (§5.2, Figure 6) measured on the *real* two-level
//! store at laptop scale: read throughput vs (data size × skip size), with
//! the memory tier capacity placed so the surface shows both ridges and
//! the capacity cliff, exactly like the paper's Figure 6 shape.
//!
//! Run: `cargo run --release --example storage_mountain [-- --quick]`

use tlstore::cli::Args;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ReadMode, WriteMode};
use tlstore::testing::TempDir;
use tlstore::util::bytes::fmt_bytes;
use tlstore::util::rng::Pcg32;

/// Measure effective read throughput over `data` with `skip` bytes
/// skipped per 256 KiB request (scaled-down analogue of the paper's 1 MB).
fn measure(store: &TwoLevelStore, key: &str, size: u64, skip: u64, request: u64) -> f64 {
    let t = std::time::Instant::now();
    let mut off = 0u64;
    let mut bytes = 0u64;
    while off < size {
        let take = request.min(size - off);
        let got = store
            .read_range(key, off, take as usize, ReadMode::TwoLevel)
            .unwrap();
        bytes += got.len() as u64;
        off += take + skip;
    }
    bytes as f64 / 1e6 / t.elapsed().as_secs_f64()
}

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let args = Args::parse(std::env::args().skip(1))?;
    let quick = args.has("quick");
    args.finish()?;

    // memory tier sized to 8 MiB so the capacity cliff falls inside the
    // sweep (the paper's 16 GB cliff, scaled)
    let mem_cap: u64 = 8 << 20;
    let dir = TempDir::new("mountain").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(mem_cap)
        .block_size(256 << 10)
        .pfs_servers(4)
        .stripe_size(128 << 10)
        .build()?;
    let store = TwoLevelStore::open(cfg)?;

    let request: u64 = 256 << 10;
    let data_sizes: Vec<u64> = if quick {
        vec![2 << 20, 8 << 20, 32 << 20]
    } else {
        vec![1 << 20, 2 << 20, 4 << 20, 8 << 20, 16 << 20, 32 << 20, 64 << 20]
    };
    let skips: Vec<u64> = if quick {
        vec![0, 256 << 10, 4 << 20]
    } else {
        vec![0, 64 << 10, 256 << 10, 1 << 20, 4 << 20]
    };

    println!(
        "storage mountain on the real engine (mem tier {} — the cliff)\nthroughput in MB/s; request {}",
        fmt_bytes(mem_cap),
        fmt_bytes(request)
    );
    print!("{:>10}", "data\\skip");
    for s in &skips {
        print!("{:>10}", fmt_bytes(*s));
    }
    println!();

    let mut rng = Pcg32::new(1, 1);
    let mut cliff_check: Vec<(u64, f64)> = Vec::new();
    for &size in &data_sizes {
        let key = format!("m/{size}");
        let mut data = vec![0u8; size as usize];
        rng.fill_bytes(&mut data);
        store.write(&key, &data, WriteMode::WriteThrough)?;
        // warm pass establishes steady-state residency for this size
        let _ = measure(&store, &key, size, 0, request);

        print!("{:>10}", fmt_bytes(size));
        for &skip in &skips {
            let mbs = measure(&store, &key, size, skip, request);
            if skip == 0 {
                cliff_check.push((size, mbs));
            }
            print!("{:>10.0}", mbs);
        }
        println!();
        store.delete_all(&key)?;
    }

    // the Figure-6 shape: throughput above the capacity cliff ≫ below it
    let above: f64 = cliff_check
        .iter()
        .filter(|(s, _)| *s <= mem_cap)
        .map(|(_, t)| *t)
        .fold(0.0, f64::max);
    let below: f64 = cliff_check
        .iter()
        .filter(|(s, _)| *s >= 4 * mem_cap)
        .map(|(_, t)| *t)
        .fold(f64::INFINITY, f64::min);
    if below.is_finite() {
        println!(
            "\nTachyon-ridge / OrangeFS-ridge ratio: {:.1}× (paper: ~10× at scale)",
            above / below
        );
    }
    println!("storage_mountain OK");
    Ok(())
}

// small extension trait: delete via the ObjectStore impl
trait DeleteAll {
    fn delete_all(&self, key: &str) -> tlstore::Result<()>;
}
impl DeleteAll for TwoLevelStore {
    fn delete_all(&self, key: &str) -> tlstore::Result<()> {
        use tlstore::storage::ObjectStore;
        self.delete(key)
    }
}
