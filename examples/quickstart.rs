//! Quickstart: open a two-level store, exercise every write/read mode of
//! the paper's Figure 4, watch the tier counters move, and let the
//! coordinator checkpoint a memory-speed write in the background.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use tlstore::coordinator::{CheckpointerConfig, Coordinator};
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ReadMode, WriteMode};
use tlstore::util::bytes::fmt_bytes;

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let root = std::env::temp_dir().join(format!("tlstore-quickstart-{}", std::process::id()));

    // A small two-level store: 64 MiB memory tier over a 4-server striped
    // PFS tier, with the paper's 1 MiB / 4 MiB buffer pair.
    let cfg = TlsConfig::builder(&root)
        .mem_capacity(64 << 20)
        .block_size(1 << 20)
        .pfs_servers(4)
        .stripe_size(256 << 10)
        .build()?;
    let store = Arc::new(TwoLevelStore::open(cfg)?);
    println!("opened two-level store at {}", root.display());

    let payload: Vec<u8> = (0..(8 << 20)).map(|i| (i % 251) as u8).collect();

    // -- Figure 4 (c): synchronous write-through --------------------------
    store.write("datasets/alpha", &payload, WriteMode::WriteThrough)?;
    println!("\nwrite-through 8 MiB:");
    println!("  memory tier used : {}", fmt_bytes(store.mem_stats().used));
    println!("  pfs bytes written: {}", fmt_bytes(store.pfs_stats().bytes_written));

    // -- Figure 4 (d): memory-only read -----------------------------------
    let hot = store.read("datasets/alpha", ReadMode::MemOnly)?;
    assert_eq!(hot, payload);
    // -- Figure 4 (e): PFS-only read --------------------------------------
    let cold = store.read("datasets/alpha", ReadMode::Bypass)?;
    assert_eq!(cold, payload);

    // -- Figure 4 (f): the two-level read path, after cache pressure ------
    store.evict_object("datasets/alpha")?;
    let back = store.read("datasets/alpha", ReadMode::TwoLevel)?;
    assert_eq!(back, payload);
    let stats = store.stats();
    println!("\nafter evict + two-level read:");
    println!("  served from memory: {}", fmt_bytes(stats.mem_bytes_read));
    println!("  served from pfs   : {}", fmt_bytes(stats.pfs_bytes_read));
    println!("  observed f ratio  : {:.2}", stats.f_ratio());

    // second read is hot again (mode (f) re-cached it)
    let again = store.read("datasets/alpha", ReadMode::TwoLevel)?;
    assert_eq!(again, payload);
    println!("  f after re-read   : {:.2}", store.stats().f_ratio());

    // -- coordinator: memory-speed write + async checkpoint ---------------
    let coord = Coordinator::new(Arc::clone(&store), CheckpointerConfig::default());
    coord.write_async("datasets/beta", &payload)?;
    println!("\nasync write returned immediately; flushing checkpointer…");
    coord.flush()?;
    assert_eq!(store.read("datasets/beta", ReadMode::Bypass)?, payload);
    println!("  checkpoints       : {}", store.stats().checkpoints);
    println!("  checkpointer      : {:?}", coord.checkpointer().stats());
    coord.shutdown()?;

    std::fs::remove_dir_all(&root).ok();
    println!("\nquickstart OK");
    Ok(())
}
