//! Quickstart: open a two-level store and exercise the v2 streaming
//! surface — writer handles whose chunked appends drive the paper's §3.2
//! dual buffers, reader handles that fault blocks on demand into
//! caller-owned buffers, every Figure-4 write/read mode, and the
//! coordinator checkpointing a memory-speed write in the background.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use tlstore::coordinator::{CheckpointerConfig, Coordinator};
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectReader as _, ObjectStore, ObjectWriter as _, ReadMode, WriteMode};
use tlstore::util::bytes::fmt_bytes;

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let root = std::env::temp_dir().join(format!("tlstore-quickstart-{}", std::process::id()));

    // A small two-level store: 64 MiB memory tier over a 4-server striped
    // PFS tier, with the paper's 1 MiB / 4 MiB buffer pair.
    let cfg = TlsConfig::builder(&root)
        .mem_capacity(64 << 20)
        .block_size(1 << 20)
        .pfs_servers(4)
        .stripe_size(256 << 10)
        .build()?;
    let store = Arc::new(TwoLevelStore::open(cfg)?);
    println!("opened two-level store at {}", root.display());

    let payload: Vec<u8> = (0..(8 << 20)).map(|i| (i % 251) as u8).collect();

    // -- Figure 4 (c): streaming write-through ----------------------------
    // Each 1 MiB append streams to the striped PFS temp files *and* fills
    // the memory tier's block accumulators; commit publishes atomically.
    let mut w = store.create_with("datasets/alpha", WriteMode::WriteThrough)?;
    for chunk in payload.chunks(1 << 20) {
        w.append(chunk)?;
    }
    w.commit()?;
    println!("\nstreamed 8 MiB write-through (1 MiB appends):");
    println!("  memory tier used : {}", fmt_bytes(store.mem_stats().used));
    println!("  pfs bytes written: {}", fmt_bytes(store.pfs_stats().bytes_written));

    // -- stat() subsumes size/exists --------------------------------------
    let meta = store.stat("datasets/alpha")?;
    println!("  stat             : {} = {}", meta.key, fmt_bytes(meta.size));

    // -- Figure 4 (d): memory-only read -----------------------------------
    let hot = store.read("datasets/alpha", ReadMode::MemOnly)?;
    assert_eq!(hot, payload);
    // -- Figure 4 (e): PFS-only read --------------------------------------
    let cold = store.read("datasets/alpha", ReadMode::Bypass)?;
    assert_eq!(cold, payload);

    // -- Figure 4 (f): the streaming two-level read path ------------------
    // After cache pressure, a reader handle faults only the blocks each
    // read_at touches back into the memory tier — into a caller-owned
    // buffer, no whole-object materialization.
    store.evict_object("datasets/alpha")?;
    let reader = store.open_with("datasets/alpha", ReadMode::TwoLevel)?;
    let mut buf = vec![0u8; 1 << 20];
    let mut off = 0u64;
    while off < reader.len() {
        let n = reader.read_at(off, &mut buf)?;
        assert_eq!(&buf[..n], &payload[off as usize..off as usize + n]);
        off += n as u64;
    }
    drop(reader);
    let stats = store.stats();
    println!("\nafter evict + streaming two-level read:");
    println!("  served from memory: {}", fmt_bytes(stats.mem_bytes_read));
    println!("  served from pfs   : {}", fmt_bytes(stats.pfs_bytes_read));
    println!("  observed f ratio  : {:.2}", stats.f_ratio());

    // the faulted blocks were cached: a second pass is hot
    let again = store.read("datasets/alpha", ReadMode::TwoLevel)?;
    assert_eq!(again, payload);
    println!("  f after re-read   : {:.2}", store.stats().f_ratio());

    // -- abort: a writer that never commits leaves nothing ----------------
    let mut scratch = store.create_with("datasets/scratch", WriteMode::WriteThrough)?;
    scratch.append(&payload[..1 << 20])?;
    scratch.abort()?;
    assert!(!store.exists("datasets/scratch"));
    println!("\naborted writer left no trace (exists = false)");

    // -- coordinator: memory-speed write + async checkpoint ---------------
    let coord = Coordinator::new(Arc::clone(&store), CheckpointerConfig::default());
    coord.write_async("datasets/beta", &payload)?;
    println!("async write returned immediately; flushing checkpointer…");
    coord.flush()?;
    assert_eq!(store.read("datasets/beta", ReadMode::Bypass)?, payload);
    println!("  checkpoints       : {}", store.stats().checkpoints);
    println!("  checkpointer      : {:?}", coord.checkpointer().stats());
    coord.shutdown()?;

    std::fs::remove_dir_all(&root).ok();
    println!("\nquickstart OK");
    Ok(())
}
