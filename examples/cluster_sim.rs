//! Simulate the paper's §5 Palmetto experiment (16 compute × 16
//! containers, 2 data nodes) and print Figure-7-style utilization
//! sparklines plus the Figure 7(f–g) phase-time comparisons.
//!
//! Run: `cargo run --release --example cluster_sim`

use tlstore::sim::{simulate_terasort, BackendKind, SimConstants};

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let constants = SimConstants::default();
    let (n, m, containers, gb) = (16, 2, 16, 16.0);
    println!(
        "simulated testbed: {n} compute nodes × {containers} containers, {m} data nodes, {gb} GB TeraSort"
    );
    println!("(constants from Table 3 / §5.1: disk 60, RAID 400r/200w, NIC 1170, RAM 6267 MB/s)\n");

    let mut reports = Vec::new();
    for backend in [
        BackendKind::Hdfs,
        BackendKind::Ofs,
        BackendKind::Tls { f_pct: 100 },
    ] {
        let r = simulate_terasort(backend, n, m, containers, gb, constants)?;
        println!("=== {} ===", r.backend);
        println!("map phase ({:.1}s):", r.map_time);
        for series in ["cpu0", "disk0", "ram0", "nic0", "raidr0", "dnic0"] {
            if let Some(tl) = r.result_map.timelines.get(series) {
                println!(
                    "  {:<8} {}  mean={:4.0}% peak={:4.0}%",
                    series,
                    tl.sparkline(40),
                    tl.mean() * 100.0,
                    tl.peak() * 100.0
                );
            }
        }
        println!("reduce phase ({:.1}s):", r.reduce_time);
        for series in ["cpu0", "disk0", "nic0", "raidw0", "dnic0"] {
            if let Some(tl) = r.result_reduce.timelines.get(series) {
                println!(
                    "  {:<8} {}  mean={:4.0}% peak={:4.0}%",
                    series,
                    tl.sparkline(40),
                    tl.mean() * 100.0,
                    tl.peak() * 100.0
                );
            }
        }
        println!();
        reports.push(r);
    }

    let hdfs = &reports[0];
    let ofs = &reports[1];
    let tls = &reports[2];
    println!("Figure 7(f) — mapper speedup of two-level storage:");
    println!(
        "  vs HDFS: {:.1}× (paper: 5.4×)   vs OrangeFS: {:.1}× (paper: 4.2×)",
        hdfs.map_time / tls.map_time,
        ofs.map_time / tls.map_time
    );

    println!("\nFigure 7(g) — reduce-phase scaling with data nodes (two-level):");
    let r2 = simulate_terasort(BackendKind::Tls { f_pct: 100 }, n, 2, containers, gb, constants)?;
    for dm in [4usize, 12] {
        let r = simulate_terasort(BackendKind::Tls { f_pct: 100 }, n, dm, containers, gb, constants)?;
        println!(
            "  {dm:>2} data nodes: reduce {:.1}s → {:.1}× vs 2 nodes (paper: {})",
            r.reduce_time,
            r2.reduce_time / r.reduce_time,
            if dm == 4 { "1.9×" } else { "4.5×" }
        );
    }
    println!("\ncluster_sim OK");
    Ok(())
}
