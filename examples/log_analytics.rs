//! Log analytics on the two-level store, driven through the Job API v2:
//! a [`tlstore::mapreduce::JobServer`] runs **two jobs concurrently**
//! against one store —
//!
//! 1. the two-round **log-sessionization pipeline**
//!    ([`tlstore::workloads::sessions`]): interleaved event logs →
//!    per-user sessions → session-length histogram, verified against the
//!    generator's ground truth; and
//! 2. (when `artifacts/` is built) the **kernel analytics job**: wide
//!    numeric event tables aggregated by the AOT-compiled Pallas
//!    column-stats kernel via PJRT, expressed as a single-round
//!    [`tlstore::mapreduce::PipelineSpec`] over the same server.
//!
//! Every intermediate byte of both jobs spills through `.shuffle/`
//! objects on the two-level store (the default spill threshold), so this
//! example is also a live demonstration of the shuffle riding the
//! paper's write-through and priority-read paths.
//!
//! Run: `cargo run --release --example log_analytics`
//! (`make artifacts` enables the kernel job; without it the example runs
//! the sessionization pipeline alone.)

use std::path::Path;
use std::sync::Arc;

use tlstore::analytics::{generate_tables, parse_report_line, AggReducer, RowMapper};
use tlstore::mapreduce::{JobServerConfig, PipelineSpec};
use tlstore::runtime::Runtime;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::{ObjectStore, SHUFFLE_NS};
use tlstore::testing::TempDir;
use tlstore::workloads::sessions;

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let dir = TempDir::new("log-analytics").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(128 << 20)
        .block_size(1 << 20)
        .pfs_servers(4)
        .stripe_size(256 << 10)
        .build()?;
    let store: Arc<dyn ObjectStore> = Arc::new(TwoLevelStore::open(cfg)?);
    let server = tlstore::mapreduce::JobServer::new(
        Arc::clone(&store),
        JobServerConfig {
            max_concurrent_jobs: 2,
            ..JobServerConfig::default()
        },
    );

    // ---- job 1: log sessionization (two rounds, no kernel needed) ------
    let users = 24u32;
    let bytes = sessions::generate_logs(store.as_ref(), "logs/in/", users, 60, 7)?;
    println!("wrote {bytes} bytes of interleaved event logs for {users} users");
    let session_job = server.submit(sessions::pipeline("logs/in/", "logs/out/", 4)?)?;
    println!("submitted {} as {}", session_job.name(), session_job.id());

    // ---- job 2: kernel analytics over event tables (needs artifacts) ---
    let kernel_job = match Runtime::load_dir(Path::new("artifacts")) {
        Ok(rt) => {
            let runtime = Arc::new(rt);
            println!("PJRT: {}", runtime.platform());
            let tables = 12u32;
            let rows = 6000usize;
            let expected = generate_tables(store.as_ref(), "events/", tables, rows, 7)?;
            let spec = PipelineSpec::builder("log-analytics")
                .input("events/")
                .output("stats/")
                .split_size(u64::MAX) // rows must stay whole per table
                .map(Arc::new(RowMapper))
                .reduce(Arc::new(AggReducer { runtime }), 4)
                .build()?;
            let handle = server.submit(spec)?;
            println!("submitted {} as {}", handle.name(), handle.id());
            Some((handle, expected, tables, rows))
        }
        Err(e) => {
            println!("artifacts not loaded ({e}) — running sessionization only");
            None
        }
    };

    // ---- join + verify --------------------------------------------------
    let stats = session_job.join()?;
    println!("{}", stats.report());
    assert!(stats.spilled_runs() > 0, "shuffle must ride the store");
    let summary = sessions::verify_histogram(store.as_ref(), "logs/in/", "logs/out/")?;
    for key in store.list("logs/out/") {
        print!("{}", String::from_utf8_lossy(&store.read(&key)?));
    }
    println!("sessionization {summary}");

    if let Some((handle, expected, tables, rows)) = kernel_job {
        let stats = handle.join()?;
        println!("{}", stats.report());
        let mut verified = 0;
        for key in store.list("stats/") {
            let text = String::from_utf8(store.read(&key)?).expect("utf8 report");
            print!("{text}");
            for line in text.lines() {
                let st = parse_report_line(line).expect("parseable report line");
                let want = expected[st.table_id as usize][0];
                assert!(
                    (st.mean[0] - want).abs() < 0.05,
                    "table {} c0: kernel {} vs generator {}",
                    st.table_id,
                    st.mean[0],
                    want
                );
                assert_eq!(st.rows as usize, rows);
                verified += 1;
            }
        }
        assert_eq!(verified, tables);
        println!("all {verified} table means match the generator through the PJRT kernel");
    }

    server.shutdown()?;
    assert!(store.list(SHUFFLE_NS).is_empty(), "shuffle namespace clean");
    println!("log_analytics OK");
    Ok(())
}
