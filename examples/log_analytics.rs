//! Log analytics on the two-level store: a MapReduce job whose reducers
//! aggregate wide numeric event tables with the AOT-compiled Pallas
//! column-stats kernel via PJRT — the second workload class the paper's
//! introduction motivates (analytics over data staged in the memory tier).
//!
//! Pipeline: generate event tables → store (write-through) → MapReduce
//! ([`tlstore::analytics`]) → verify the kernel-computed means against the
//! generator's ground truth.
//!
//! Run: `cargo run --release --example log_analytics`
//! Requires `make artifacts`.

use std::path::Path;
use std::sync::Arc;

use tlstore::analytics::{generate_tables, parse_report_line, run_analytics};
use tlstore::mapreduce::Engine;
use tlstore::runtime::Runtime;
use tlstore::storage::tls::{TlsConfig, TwoLevelStore};
use tlstore::storage::ObjectStore;
use tlstore::testing::TempDir;

fn main() -> tlstore::Result<()> {
    tlstore::util::logger::init();
    let runtime = Arc::new(Runtime::load_dir(Path::new("artifacts"))?);
    println!("PJRT: {}", runtime.platform());

    let dir = TempDir::new("log-analytics").unwrap();
    let cfg = TlsConfig::builder(dir.path())
        .mem_capacity(128 << 20)
        .block_size(1 << 20)
        .pfs_servers(4)
        .stripe_size(256 << 10)
        .build()?;
    let store: Arc<dyn ObjectStore> = Arc::new(TwoLevelStore::open(cfg)?);

    let tables = 12u32;
    let rows = 6000usize;
    let expected = generate_tables(store.as_ref(), "events/", tables, rows, 7)?;
    println!("wrote {tables} tables × {rows} rows × 8 cols into the two-level store");

    let engine = Engine::local();
    let stats = run_analytics(
        &engine,
        Arc::clone(&store),
        Arc::clone(&runtime),
        "events/",
        "stats/",
        4,
    )?;
    println!("{}", stats.report());

    // verify every table's c0 mean against the generator's ground truth
    let mut verified = 0;
    for key in store.list("stats/") {
        let text = String::from_utf8(store.read(&key)?).expect("utf8 report");
        print!("{text}");
        for line in text.lines() {
            let st = parse_report_line(line).expect("parseable report line");
            let want = expected[st.table_id as usize][0];
            assert!(
                (st.mean[0] - want).abs() < 0.05,
                "table {} c0: kernel {} vs generator {}",
                st.table_id,
                st.mean[0],
                want
            );
            assert_eq!(st.rows as usize, rows);
            verified += 1;
        }
    }
    assert_eq!(verified, tables);
    println!("\nall {verified} table means match the generator through the PJRT kernel");
    println!("log_analytics OK");
    Ok(())
}
