"""AOT lowering: HLO-text emission, manifest contents, numeric equivalence
of the lowered computation re-executed through the XLA client."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


class TestToHloText:
    def test_emits_parseable_hlo_text(self):
        name, fn, args = model.entry_points()[0]
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # no Mosaic custom-calls may survive (interpret=True requirement)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()

    def test_spec_strings(self):
        _, _, out = aot.lower_entry(*model.entry_points()[0])
        assert out == ["u32[64x256]", "s32[64x256]", "s32[256]"]
        _, inp, out = aot.lower_entry(*model.entry_points()[1])
        assert inp == ["f32[4096x8]"]
        assert out == ["f32[4x8]", "f32[8]", "f32[8]"]


class TestManifest:
    def test_main_writes_all_artifacts(self, tmp_path, monkeypatch):
        monkeypatch.setattr(
            "sys.argv", ["aot", "--outdir", str(tmp_path)]
        )
        aot.main()
        files = sorted(os.listdir(tmp_path))
        assert files == [
            "analytics_agg.hlo.txt",
            "manifest.toml",
            "sort_block.hlo.txt",
        ]
        manifest = (tmp_path / "manifest.toml").read_text()
        assert "[sort_block]" in manifest and "[analytics_agg]" in manifest
        assert 'inputs = ["u32[64x256]"]' in manifest


class TestLoweredNumerics:
    """Execute the lowered module through the raw XLA client and compare to
    direct jax execution.  (The HLO-*text* leg of the interchange is
    integration-tested from Rust in rust/tests/integration_runtime.rs, which
    loads artifacts/*.hlo.txt through the same PJRT client the coordinator
    uses and checks these exact numerics.)"""

    def test_sort_block_roundtrip(self):
        from jaxlib import _jax

        name, fn, args = model.entry_points()[0]
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert len(text) > 1000

        rng = np.random.default_rng(20)
        k = rng.integers(0, 2**32, size=(model.SORT_TILES, model.SORT_LANE), dtype=np.uint64).astype(np.uint32)
        direct = fn(jnp.asarray(k))

        backend = jax.devices("cpu")[0].client
        devices = _jax.DeviceList(tuple(backend.local_devices()))
        exe = backend.compile_and_load(str(lowered.compiler_ir("stablehlo")), devices)
        outs = exe.execute_sharded(
            [backend.buffer_from_pyval(k)]
        ).disassemble_into_single_device_arrays()
        got = [np.asarray(o[0]) for o in outs]
        assert len(got) == 3
        for g, d in zip(got, direct):
            assert (g == np.asarray(d)).all()
