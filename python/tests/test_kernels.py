"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes and value distributions; every property asserts
exact equality for integer outputs and allclose for float outputs.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels import aggregate, ref, sortnet

_SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _keys(rng, tiles, lane, lo=0, hi=2**32):
    return jnp.asarray(
        rng.integers(lo, hi, size=(tiles, lane), dtype=np.uint64).astype(np.uint32)
    )


# ---------------------------------------------------------------- sortnet


class TestSortBlockFixedShape:
    """The exact AOT shape (TILES × LANE) — the contract Rust relies on."""

    def test_random_uniform(self):
        rng = np.random.default_rng(1)
        k = _keys(rng, sortnet.TILES, sortnet.LANE)
        s, p, h = sortnet.sort_block(k)
        rs, rp, rh = ref.sort_block_ref(k)
        assert (np.asarray(s) == np.asarray(rs)).all()
        assert (np.asarray(p) == np.asarray(rp)).all()
        assert (np.asarray(h) == np.asarray(rh)).all()

    def test_all_equal_keys(self):
        k = jnp.full((sortnet.TILES, sortnet.LANE), 0xDEADBEEF, jnp.uint32)
        s, p, h = sortnet.sort_block(k)
        assert (np.asarray(s) == 0xDEADBEEF).all()
        # stable: perm must be the identity within each tile
        assert (np.asarray(p) == np.arange(sortnet.LANE, dtype=np.int32)).all()
        assert np.asarray(h).sum() == sortnet.TILES * sortnet.LANE

    def test_already_sorted_and_reversed(self):
        base = np.arange(sortnet.LANE, dtype=np.uint32) * 7919
        asc = jnp.asarray(np.tile(base, (sortnet.TILES, 1)))
        desc = jnp.asarray(np.tile(base[::-1].copy(), (sortnet.TILES, 1)))
        for k in (asc, desc):
            s, p, h = sortnet.sort_block(k)
            rs, rp, rh = ref.sort_block_ref(k)
            assert (np.asarray(s) == np.asarray(rs)).all()
            assert (np.asarray(p) == np.asarray(rp)).all()
            assert (np.asarray(h) == np.asarray(rh)).all()

    def test_extreme_values(self):
        rng = np.random.default_rng(2)
        k = np.asarray(_keys(rng, sortnet.TILES, sortnet.LANE)).copy()
        k[0, :8] = 0
        k[0, 8:16] = 0xFFFFFFFF
        k = jnp.asarray(k)
        s, p, h = sortnet.sort_block(k)
        rs, rp, rh = ref.sort_block_ref(k)
        assert (np.asarray(s) == np.asarray(rs)).all()
        assert (np.asarray(h) == np.asarray(rh)).all()

    def test_histogram_counts_total(self):
        rng = np.random.default_rng(3)
        k = _keys(rng, sortnet.TILES, sortnet.LANE)
        _, _, h = sortnet.sort_block(k)
        assert np.asarray(h).sum() == sortnet.TILES * sortnet.LANE

    def test_perm_is_bijection_per_tile(self):
        rng = np.random.default_rng(4)
        # heavy duplicates stress the tie-breaking
        k = _keys(rng, sortnet.TILES, sortnet.LANE, hi=16)
        _, p, _ = sortnet.sort_block(k)
        p = np.asarray(p)
        for t in range(sortnet.TILES):
            assert sorted(p[t].tolist()) == list(range(sortnet.LANE))


class TestSortBlockShapeSweep:
    """hypothesis sweep over tile counts, lane widths, and key ranges."""

    @settings(**_SETTINGS)
    @given(
        tiles=st.integers(min_value=1, max_value=8),
        lane_exp=st.integers(min_value=1, max_value=8),
        hi=st.sampled_from([2, 7, 256, 2**16, 2**32]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_oracle(self, tiles, lane_exp, hi, seed):
        rng = np.random.default_rng(seed)
        k = _keys(rng, tiles, 1 << lane_exp, hi=hi)
        s, p, h = sortnet.sort_block_sized(k)
        rs, rp, rh = ref.sort_block_ref(k)
        assert (np.asarray(s) == np.asarray(rs)).all()
        assert (np.asarray(p) == np.asarray(rp)).all()
        assert (np.asarray(h) == np.asarray(rh)).all()

    @settings(**_SETTINGS)
    @given(
        tiles=st.integers(min_value=1, max_value=4),
        lane_exp=st.integers(min_value=1, max_value=7),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_sorted_is_permutation_of_input(self, tiles, lane_exp, seed):
        rng = np.random.default_rng(seed)
        k = _keys(rng, tiles, 1 << lane_exp)
        s, p, _ = sortnet.sort_block_sized(k)
        s, p, k = np.asarray(s), np.asarray(p), np.asarray(k)
        for t in range(tiles):
            assert sorted(s[t].tolist()) == sorted(k[t].tolist())
            assert (k[t][p[t]] == s[t]).all()
            assert (np.diff(s[t].astype(np.int64)) >= 0).all()

    def test_rejects_non_pow2_lane(self):
        k = jnp.zeros((2, 100), jnp.uint32)
        with pytest.raises(AssertionError):
            sortnet.sort_block_sized(k)


# -------------------------------------------------------------- aggregate


class TestColumnStatsFixedShape:
    def test_random_normal(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(aggregate.ROWS, aggregate.COLS)).astype(np.float32))
        st_ = aggregate.column_stats(x)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(ref.column_stats_ref(x)), rtol=1e-5, atol=1e-4)

    def test_constant_columns(self):
        x = jnp.full((aggregate.ROWS, aggregate.COLS), 3.5, jnp.float32)
        st_ = np.asarray(aggregate.column_stats(x))
        np.testing.assert_allclose(st_[0], aggregate.ROWS * 3.5, rtol=1e-6)
        np.testing.assert_allclose(st_[1], 3.5)
        np.testing.assert_allclose(st_[2], 3.5)
        np.testing.assert_allclose(st_[3], aggregate.ROWS * 3.5**2, rtol=1e-6)

    def test_negative_and_mixed_sign(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray((rng.normal(size=(aggregate.ROWS, aggregate.COLS)) * 100 - 50).astype(np.float32))
        st_ = aggregate.column_stats(x)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(ref.column_stats_ref(x)), rtol=1e-4, atol=1e-2)


class TestColumnStatsShapeSweep:
    @settings(**_SETTINGS)
    @given(
        chunks=st.integers(min_value=1, max_value=8),
        chunk=st.sampled_from([1, 4, 32, 128]),
        cols=st.integers(min_value=1, max_value=16),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_oracle(self, chunks, chunk, cols, scale, seed):
        rng = np.random.default_rng(seed)
        rows = chunks * chunk
        x = jnp.asarray((rng.normal(size=(rows, cols)) * scale).astype(np.float32))
        got = aggregate.column_stats_sized(x, chunk)
        want = ref.column_stats_ref(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5 * scale)

    def test_rejects_misaligned_chunk(self):
        x = jnp.zeros((10, 4), jnp.float32)
        with pytest.raises(AssertionError):
            aggregate.column_stats_sized(x, 3)


# ----------------------------------------------------- structural / perf


class TestKernelStructure:
    """DESIGN.md §Perf structural assertions — VMEM residency targets."""

    def test_sortnet_vmem_fits(self):
        # per-grid-step working set must fit in a 16 MiB VMEM with headroom
        # (TILE_BLOCK=16 carries a 4 MiB one-hot scratch — the perf sweep's
        # winner; see EXPERIMENTS.md §Perf)
        assert sortnet.vmem_footprint_bytes() < 8 * 1024 * 1024

    def test_aggregate_vmem_fits(self):
        assert aggregate.vmem_footprint_bytes() < 4 * 1024 * 1024

    def test_bitonic_stage_count(self):
        # O(log² n): n=256 → 8*9/2 = 36 compare-exchange stages
        log2n = sortnet.LANE.bit_length() - 1
        assert log2n * (log2n + 1) // 2 == 36
