"""L2 model correctness: entry points vs oracles, shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


class TestTerasortBlock:
    def test_matches_oracle(self):
        rng = np.random.default_rng(10)
        k = jnp.asarray(
            rng.integers(0, 2**32, size=(model.SORT_TILES, model.SORT_LANE), dtype=np.uint64).astype(np.uint32)
        )
        s, p, h = model.terasort_block(k)
        rs, rp, rh = ref.terasort_block_ref(k)
        assert (np.asarray(s) == np.asarray(rs)).all()
        assert (np.asarray(p) == np.asarray(rp)).all()
        assert (np.asarray(h) == np.asarray(rh)).all()

    def test_output_shapes_and_dtypes(self):
        k = jnp.zeros((model.SORT_TILES, model.SORT_LANE), jnp.uint32)
        s, p, h = model.terasort_block(k)
        assert s.shape == (model.SORT_TILES, model.SORT_LANE) and s.dtype == jnp.uint32
        assert p.shape == (model.SORT_TILES, model.SORT_LANE) and p.dtype == jnp.int32
        assert h.shape == (model.SORT_BUCKETS,) and h.dtype == jnp.int32


class TestAnalyticsAgg:
    def test_matches_oracle(self):
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.normal(size=(model.AGG_ROWS, model.AGG_COLS)).astype(np.float32))
        stats, mean, var = model.analytics_agg(x)
        rstats, rmean, rvar = ref.analytics_agg_ref(x)
        np.testing.assert_allclose(np.asarray(stats), np.asarray(rstats), rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(np.asarray(mean), np.asarray(rmean), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(var), np.asarray(rvar), rtol=1e-3, atol=1e-4)

    def test_variance_nonnegative_for_reasonable_data(self):
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.uniform(-10, 10, size=(model.AGG_ROWS, model.AGG_COLS)).astype(np.float32))
        _, _, var = model.analytics_agg(x)
        assert (np.asarray(var) >= -1e-3).all()


class TestEntryPoints:
    def test_registry_is_complete(self):
        names = [n for n, _, _ in model.entry_points()]
        assert names == ["sort_block", "analytics_agg"]

    def test_example_args_trace(self):
        # every entry point must trace with its example args (AOT precondition)
        for _, fn, args in model.entry_points():
            jax.eval_shape(fn, *args)
