"""Layer-1 Pallas kernels for tlstore.

Every kernel here is authored with ``jax.experimental.pallas`` and lowered
under ``interpret=True`` so that the resulting HLO contains only portable ops
executable by the CPU PJRT client that the Rust runtime drives.  Real-TPU
lowering would emit Mosaic custom-calls, which are compile-only targets in
this repo (see DESIGN.md §Hardware-Adaptation).

Kernels:

- :mod:`sortnet`   — bitonic sort network over VMEM-resident key tiles plus a
  bucket histogram used by TeraSort's range partitioner.
- :mod:`aggregate` — streaming per-column statistics (sum/min/max/sumsq) used
  by the log-analytics example.
- :mod:`ref`       — pure-jnp oracles; pytest asserts kernels == oracles.
"""

from . import aggregate, ref, sortnet  # noqa: F401

__all__ = ["sortnet", "aggregate", "ref"]
