"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: pytest sweeps shapes/values with
hypothesis and asserts the kernels match these reference implementations
exactly (integer outputs) or to float tolerance (aggregates).
"""

import jax.numpy as jnp

from . import sortnet


def sort_block_ref(keys):
    """Oracle for :func:`sortnet.sort_block`.

    Tile-wise stable ascending sort along the last axis, the corresponding
    stable argsort permutation, and the bucket histogram of the *whole*
    block (bucket = top byte of the u32 key).
    """
    assert keys.dtype == jnp.uint32
    perm = jnp.argsort(keys, axis=-1, stable=True).astype(jnp.int32)
    sorted_keys = jnp.take_along_axis(keys, perm, axis=-1)
    buckets = (keys >> jnp.uint32(32 - 8)).astype(jnp.int32)
    hist = jnp.bincount(buckets.ravel(), length=sortnet.NUM_BUCKETS).astype(jnp.int32)
    return sorted_keys, perm, hist


def column_stats_ref(x):
    """Oracle for :func:`aggregate.column_stats`."""
    assert x.dtype == jnp.float32
    return jnp.stack(
        [
            jnp.sum(x, axis=0),
            jnp.min(x, axis=0),
            jnp.max(x, axis=0),
            jnp.sum(x * x, axis=0),
        ]
    )


def terasort_block_ref(keys):
    """Oracle for the L2 ``terasort_block`` entry point (same contract as
    :func:`sort_block_ref`; kept separate so model-level tests don't import
    kernel internals)."""
    return sort_block_ref(keys)


def analytics_agg_ref(x):
    """Oracle for the L2 ``analytics_agg`` entry point: raw stats plus the
    fused mean/variance epilogue computed in plain jnp."""
    stats = column_stats_ref(x)
    n = jnp.float32(x.shape[0])
    mean = stats[0] / n
    var = stats[3] / n - mean * mean
    return stats, mean, var


__all__ = [
    "sort_block_ref",
    "column_stats_ref",
    "terasort_block_ref",
    "analytics_agg_ref",
]
