"""Streaming per-column statistics Pallas kernel.

The log-analytics example reduces wide numeric event tables that are read
out of the two-level store: for each column it needs sum / min / max / sum of
squares (count is static).  The kernel streams row chunks HBM→VMEM via the
grid and keeps a single ``(4, COLS)`` accumulator block resident across all
grid steps — a classic reduction BlockSpec schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed AOT shapes — must match the manifest emitted by aot.py.
ROWS = 4096
COLS = 8
CHUNK = 512  # rows per grid step
assert ROWS % CHUNK == 0
STAT_ROWS = 4  # sum, min, max, sumsq


def column_stats_sized(x, chunk=None):
    """Shape-generic variant of :func:`column_stats` — any ``(rows, cols)``
    f32 table with ``rows % chunk == 0``.  Used by the hypothesis sweep; the
    AOT artifact pins :data:`ROWS`×:data:`COLS`."""
    rows, cols = x.shape
    assert x.dtype == jnp.float32, x.dtype
    chunk = chunk or min(CHUNK, rows)
    assert rows % chunk == 0, (rows, chunk)

    def kernel(x_ref, stats_ref):
        xv = x_ref[...]
        chunk_stats = jnp.stack(
            [
                jnp.sum(xv, axis=0),
                jnp.min(xv, axis=0),
                jnp.max(xv, axis=0),
                jnp.sum(xv * xv, axis=0),
            ]
        )

        @pl.when(pl.program_id(0) == 0)
        def _init():
            stats_ref[...] = jnp.stack(
                [
                    jnp.zeros((cols,), jnp.float32),
                    jnp.full((cols,), jnp.inf, jnp.float32),
                    jnp.full((cols,), -jnp.inf, jnp.float32),
                    jnp.zeros((cols,), jnp.float32),
                ]
            )

        acc = stats_ref[...]
        stats_ref[...] = jnp.stack(
            [
                acc[0] + chunk_stats[0],
                jnp.minimum(acc[1], chunk_stats[1]),
                jnp.maximum(acc[2], chunk_stats[2]),
                acc[3] + chunk_stats[3],
            ]
        )

    return pl.pallas_call(
        kernel,
        grid=(rows // chunk,),
        in_specs=[pl.BlockSpec((chunk, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((STAT_ROWS, cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((STAT_ROWS, cols), jnp.float32),
        interpret=True,
    )(x)


@functools.partial(jax.jit, static_argnames=())
def column_stats(x):
    """Per-column (sum, min, max, sumsq) of an ``(ROWS, COLS)`` f32 table.

    Returns ``f32[STAT_ROWS, COLS]`` with rows in that order.
    """
    assert x.shape == (ROWS, COLS) and x.dtype == jnp.float32, (x.shape, x.dtype)
    return column_stats_sized(x, CHUNK)


def vmem_footprint_bytes():
    """Static VMEM estimate per grid step (DESIGN.md §Perf)."""
    return CHUNK * COLS * 4 + STAT_ROWS * COLS * 4
