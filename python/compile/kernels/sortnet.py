"""Bitonic sort-network + partition-histogram Pallas kernel.

This is the compute hot-spot of the TeraSort mapper: each storage block of
4-byte big-endian key prefixes is sorted on-chip and simultaneously bucketed
into ``NUM_BUCKETS`` range-partition counts.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over key
*tiles*; BlockSpec pulls one ``(1, LANE)`` tile from HBM into VMEM per step,
the full O(log² LANE) compare-exchange network runs entirely on-chip
(VPU-vectorized across the lane dimension), and the histogram is accumulated
via a one-hot matmul (MXU-eligible) into a single VMEM-resident output block
shared by all grid steps.  Keys never round-trip to HBM mid-sort — the
analogue of the paper keeping the working set in the Tachyon RAM tier
instead of spilling to OrangeFS.

The kernel sorts a companion ``perm`` array with lexicographic (key, perm)
tie-breaking, so the output permutation is valid even with duplicate keys and
the overall sort is stable.  The Rust mapper applies ``perm`` to full
records and k-way-merges the sorted tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed AOT shapes — these must match rust/src/terasort (TILES/LANE) and the
# manifest emitted by aot.py.
TILES = 64  # tiles per kernel invocation
LANE = 256  # keys per tile; power of two (bitonic requirement)
# Tiles per VMEM block (grid step). Perf note (EXPERIMENTS.md §Perf): the
# compare-exchange network is identical per tile, so processing several
# tiles per grid step vectorizes every stage across the tile dimension —
# fewer, fatter ops. 16×256 u32 tiles per step won the ablation sweep (EXPERIMENTS.md §Perf):
# 2.2× the single-tile-per-step rate through the rust PJRT path.
TILE_BLOCK = 16
assert TILES % TILE_BLOCK == 0
NUM_BUCKETS = 256  # range-partition buckets (top byte of the u32 key)
_LOG2_LANE = LANE.bit_length() - 1


def _compare_exchange(keys, perm, j, k):
    """One bitonic compare-exchange stage along the last axis.

    Position ``i`` pairs with ``i ^ j``; the direction of the (i, i^j)
    exchange flips with bit ``k`` of ``i``.  Ties on the key are broken by
    ``perm`` so the exchange is a strict lexicographic comparison — this
    keeps the permutation a bijection even with duplicate keys.
    """
    idx = jnp.arange(keys.shape[-1], dtype=jnp.int32)
    partner = idx ^ j
    pkeys = keys[..., partner]
    pperm = perm[..., partner]

    up = (idx & k) == 0  # ascending region?
    is_lower = (idx & j) == 0  # lower index of the pair?
    want_small = jnp.where(up, is_lower, ~is_lower)

    partner_less = (pkeys < keys) | ((pkeys == keys) & (pperm < perm))
    partner_greater = (pkeys > keys) | ((pkeys == keys) & (pperm > perm))
    take_partner = jnp.where(want_small, partner_less, partner_greater)

    keys = jnp.where(take_partner, pkeys, keys)
    perm = jnp.where(take_partner, pperm, perm)
    return keys, perm


def bitonic_sort_with_perm(keys, perm):
    """Full bitonic network: sorts ``keys`` ascending along the last axis,
    applying identical exchanges to ``perm``.  Shapes are static so the
    O(log² n) stage loop unrolls at trace time into a fixed HLO DAG."""
    n = keys.shape[-1]
    assert n & (n - 1) == 0, "bitonic sort needs a power-of-two lane count"
    log2n = n.bit_length() - 1
    for k_exp in range(1, log2n + 1):
        k = 1 << k_exp
        for j_exp in range(k_exp - 1, -1, -1):
            keys, perm = _compare_exchange(keys, perm, 1 << j_exp, k)
    return keys, perm


def _bucket_of(keys):
    """Range-partition bucket: top byte of the big-endian u32 key prefix."""
    return (keys >> jnp.uint32(32 - 8)).astype(jnp.int32)


def _make_kernel(lane):
    """Kernel body closed over the (static) lane width; handles any number
    of tiles per block (every stage vectorizes across the tile dim)."""

    def kernel(keys_ref, sorted_ref, perm_ref, hist_ref):
        keys = keys_ref[...]  # (tile_block, lane) u32, VMEM-resident
        n = keys.shape[0] * keys.shape[1]
        perm0 = jax.lax.broadcasted_iota(jnp.int32, keys.shape, dimension=1)
        skeys, sperm = bitonic_sort_with_perm(keys, perm0)
        sorted_ref[...] = skeys
        perm_ref[...] = sperm

        one_hot = (
            _bucket_of(keys).reshape(n, 1) == jnp.arange(NUM_BUCKETS, dtype=jnp.int32)
        ).astype(jnp.float32)
        tile_hist = jnp.dot(jnp.ones((1, n), jnp.float32), one_hot)

        @pl.when(pl.program_id(0) == 0)
        def _init():
            hist_ref[...] = jnp.zeros_like(hist_ref)

        hist_ref[...] += tile_hist.reshape(NUM_BUCKETS).astype(jnp.int32)

    return kernel


def sort_block_sized(keys, tile_block=1):
    """Shape-generic variant of :func:`sort_block` — any ``(tiles, lane)``
    u32 array with a power-of-two lane count, processing ``tile_block``
    tiles per grid step.  Used by the hypothesis shape sweep; the AOT
    artifact pins :data:`TILES`×:data:`LANE` with :data:`TILE_BLOCK`."""
    tiles, lane = keys.shape
    assert keys.dtype == jnp.uint32, keys.dtype
    assert lane & (lane - 1) == 0, "lane must be a power of two"
    assert tiles % tile_block == 0, (tiles, tile_block)
    return pl.pallas_call(
        _make_kernel(lane),
        grid=(tiles // tile_block,),
        in_specs=[pl.BlockSpec((tile_block, lane), lambda t: (t, 0))],
        out_specs=[
            pl.BlockSpec((tile_block, lane), lambda t: (t, 0)),
            pl.BlockSpec((tile_block, lane), lambda t: (t, 0)),
            # Single histogram block shared by every grid step (accumulator).
            pl.BlockSpec((NUM_BUCKETS,), lambda t: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, lane), jnp.uint32),
            jax.ShapeDtypeStruct((tiles, lane), jnp.int32),
            jax.ShapeDtypeStruct((NUM_BUCKETS,), jnp.int32),
        ],
        interpret=True,
    )(keys)


@functools.partial(jax.jit, static_argnames=())
def sort_block(keys):
    """Sort ``(TILES, LANE)`` u32 keys tile-wise; also return the in-tile
    permutation and the block's partition histogram.

    Returns ``(sorted_keys u32[TILES,LANE], perm s32[TILES,LANE],
    hist s32[NUM_BUCKETS])``.
    """
    assert keys.shape == (TILES, LANE) and keys.dtype == jnp.uint32, (
        keys.shape,
        keys.dtype,
    )
    return sort_block_sized(keys, TILE_BLOCK)


def vmem_footprint_bytes():
    """Static VMEM estimate per grid step (DESIGN.md §Perf): input block +
    sorted block + perm block + histogram accumulator + one-hot scratch."""
    block = TILE_BLOCK * LANE * 4
    one_hot = TILE_BLOCK * LANE * NUM_BUCKETS * 4
    hist = NUM_BUCKETS * 4
    return 3 * block + one_hot + hist
