"""Layer-2 JAX model: the analytics compute graphs that tlstore AOT-compiles.

Two entry points, both jitted once and lowered to HLO text by ``aot.py``:

- :func:`terasort_block` — the TeraSort mapper hot-spot.  Calls the Pallas
  bitonic sort-network kernel (L1) on a block of u32 key prefixes and
  returns sorted keys, the in-tile permutation, and the range-partition
  histogram that drives the reducer assignment.
- :func:`analytics_agg` — the log-analytics reduction.  Calls the Pallas
  streaming column-stats kernel (L1) and fuses the mean/variance epilogue
  into the same HLO module so Rust gets finished statistics in one call.

Python only ever runs at build time; the Rust runtime loads the lowered HLO
via PJRT and executes it on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import aggregate, sortnet

# Re-exported static shapes (single source of truth for aot.py + manifest).
SORT_TILES = sortnet.TILES
SORT_LANE = sortnet.LANE
SORT_BUCKETS = sortnet.NUM_BUCKETS
AGG_ROWS = aggregate.ROWS
AGG_COLS = aggregate.COLS
AGG_STAT_ROWS = aggregate.STAT_ROWS


def terasort_block(keys):
    """Sort a ``(SORT_TILES, SORT_LANE)`` u32 key block tile-wise.

    Returns ``(sorted u32[T,L], perm s32[T,L], hist s32[SORT_BUCKETS])``.
    The caller (Rust mapper) applies ``perm`` to full records and k-way
    merges the tiles; ``hist`` feeds the TeraSort range partitioner.
    """
    return sortnet.sort_block(keys)


def analytics_agg(x):
    """Aggregate an ``(AGG_ROWS, AGG_COLS)`` f32 table.

    Returns ``(stats f32[4, C] (sum,min,max,sumsq), mean f32[C],
    var f32[C])``.  The epilogue is plain jnp so XLA fuses it with the
    kernel's output block — no second pass over the table.
    """
    stats = aggregate.column_stats(x)
    n = jnp.float32(x.shape[0])
    mean = stats[0] / n
    var = stats[3] / n - mean * mean
    return stats, mean, var


def entry_points():
    """(name, fn, example_args) for every artifact aot.py must emit."""
    key_spec = jax.ShapeDtypeStruct((SORT_TILES, SORT_LANE), jnp.uint32)
    agg_spec = jax.ShapeDtypeStruct((AGG_ROWS, AGG_COLS), jnp.float32)
    return [
        ("sort_block", terasort_block, (key_spec,)),
        ("analytics_agg", analytics_agg, (agg_spec,)),
    ]
